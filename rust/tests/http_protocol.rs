//! Wire-protocol conformance + seeded random-mutation fuzz for the HTTP
//! front-end.
//!
//! Drives [`serve_connection`] directly over an in-memory stream (instead
//! of TCP) with the real [`CoordinatorApp`] behind it, so a panic
//! anywhere in the framing/scanner/router stack fails the test on the
//! spot, and read-boundary placement is fully deterministic. The contract
//! under fuzz:
//!
//! 1. **never a panic** — any byte stream is handled;
//! 2. **always a typed reply** — every complete (framed) request gets
//!    exactly one JSON response with a documented 2xx/4xx/5xx status, and
//!    a truncated/unframeable stream gets exactly one 4xx before close;
//! 3. **the connection survives semantic errors** — after a bad-but-framed
//!    request (e.g. malformed JSON with a correct `Content-Length`), the
//!    next request on the same connection is served normally.
//!
//! Self-contained synthetic weights; fixed seeds end to end.

mod http_common;

use std::collections::VecDeque;
use std::io::{self, Read, Write};
use std::sync::Arc;

use http_common::infer_body;
use tpu_imac::coordinator::{Coordinator, CoordinatorConfig, ModelRegistry};
use tpu_imac::deploy::DeploymentSpec;
use tpu_imac::nn::synthetic::lenet_weights_doc;
use tpu_imac::serve_http::conn::{serve_connection, ConnArena, HttpLimits};
use tpu_imac::serve_http::router::CoordinatorApp;
use tpu_imac::util::json::Json;
use tpu_imac::util::rng::Xoshiro256;

/// In-memory stream: reads hand out the scripted chunks one `read()` call
/// at a time (then EOF), writes are captured. Chunk boundaries are the
/// fuzz dimension TCP never lets a test control.
struct ChunkedStream {
    chunks: VecDeque<Vec<u8>>,
    out: Vec<u8>,
}

impl ChunkedStream {
    fn new(chunks: Vec<Vec<u8>>) -> Self {
        Self { chunks: chunks.into(), out: Vec::new() }
    }
}

impl Read for ChunkedStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let Some(chunk) = self.chunks.front_mut() else { return Ok(0) };
        let n = buf.len().min(chunk.len());
        buf[..n].copy_from_slice(&chunk[..n]);
        chunk.drain(..n);
        if chunk.is_empty() {
            self.chunks.pop_front();
        }
        Ok(n)
    }
}

impl Write for ChunkedStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.out.extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// Parse every `Content-Length`-framed response in the captured output.
/// Panics on any framing violation — a malformed response is itself a
/// protocol bug.
fn parse_responses(mut out: &[u8]) -> Vec<(u16, String)> {
    let mut responses = Vec::new();
    while !out.is_empty() {
        let head_end = out
            .windows(4)
            .position(|w| w == b"\r\n\r\n")
            .unwrap_or_else(|| panic!("unterminated response head: {:?}", lossy(out)))
            + 4;
        let head = std::str::from_utf8(&out[..head_end]).expect("response head is ASCII");
        assert!(head.starts_with("HTTP/1.1 "), "bad status line: {head:?}");
        let status: u16 = head[9..12].parse().unwrap_or_else(|_| panic!("bad status: {head:?}"));
        let content_length: usize = head
            .lines()
            .find_map(|l| l.strip_prefix("Content-Length: "))
            .and_then(|v| v.parse().ok())
            .unwrap_or_else(|| panic!("missing content-length: {head:?}"));
        let body_end = head_end + content_length;
        assert!(out.len() >= body_end, "response truncated by server: {head:?}");
        let body = String::from_utf8(out[head_end..body_end].to_vec()).expect("UTF-8 body");
        responses.push((status, body));
        out = &out[body_end..];
    }
    responses
}

fn lossy(b: &[u8]) -> String {
    String::from_utf8_lossy(&b[..b.len().min(120)]).into_owned()
}

/// Shared serving stack for all fuzz cases (building a model per case
/// would dominate the runtime). One [`CoordinatorApp`] per "connection",
/// exactly like the TCP accept loop.
struct Stack {
    coord: Coordinator,
    registry: Arc<ModelRegistry>,
    limits: HttpLimits,
}

impl Stack {
    fn start() -> Self {
        let mut rng = Xoshiro256::seed_from_u64(0xF0_22);
        let spec = DeploymentSpec::doc("lenet", lenet_weights_doc(&mut rng));
        let registry = ModelRegistry::with_specs(&[spec]).unwrap();
        let coord =
            Coordinator::start_registry(CoordinatorConfig::default(), Arc::clone(&registry))
                .unwrap();
        Self { coord, registry, limits: HttpLimits { max_head: 16 * 1024, max_body: 256 * 1024 } }
    }

    fn app(&self) -> CoordinatorApp {
        CoordinatorApp::new(
            self.coord.client(),
            Arc::clone(&self.registry),
            Arc::clone(&self.coord.metrics),
            1000,
            "artifacts".to_string(),
        )
    }

    /// Run one connection over the scripted chunks; return the parsed
    /// responses. The `serve_connection` result must be `Ok` — in-memory
    /// writes cannot fail, so any `Err` is a framing-logic bug.
    fn serve(&self, chunks: Vec<Vec<u8>>) -> Vec<(u16, String)> {
        let mut stream = ChunkedStream::new(chunks);
        let mut arena = ConnArena::new();
        let mut app = self.app();
        serve_connection(&mut stream, &mut arena, &mut app, &self.limits, &|| false)
            .expect("in-memory serve_connection must not error");
        parse_responses(&stream.out)
    }
}

/// Split `bytes` into 1..=4 chunks at rng-chosen boundaries.
fn random_split(rng: &mut Xoshiro256, bytes: &[u8]) -> Vec<Vec<u8>> {
    let cuts = (rng.next_u64() % 4) as usize;
    let mut points: Vec<usize> =
        (0..cuts).map(|_| (rng.next_u64() as usize) % (bytes.len() + 1)).collect();
    points.sort_unstable();
    let mut chunks = Vec::with_capacity(cuts + 1);
    let mut prev = 0;
    for p in points.into_iter().chain(std::iter::once(bytes.len())) {
        if p > prev {
            chunks.push(bytes[prev..p].to_vec());
            prev = p;
        }
    }
    if chunks.is_empty() {
        chunks.push(Vec::new());
    }
    chunks
}

/// The seeded mutation fuzz: hundreds of corrupted variants of a valid
/// request, delivered with random read-boundary placement. Every case
/// must produce only documented statuses and no panic; 200s are allowed
/// (some mutations leave the request semantically intact).
#[test]
fn mutation_fuzz_never_panics_and_always_answers() {
    let stack = Stack::start();
    let valid = http_common::format_request("POST", "/v1/infer", &infer_body("lenet"));
    let mut rng = Xoshiro256::seed_from_u64(0xFA_55);
    let mut status_seen = std::collections::BTreeMap::<u16, usize>::new();
    for case in 0..200u32 {
        let mut bytes = valid.clone();
        match case % 8 {
            // Truncation at a random byte.
            0 => bytes.truncate((rng.next_u64() as usize) % bytes.len()),
            // Random single-byte corruption (possibly multiple).
            1 => {
                for _ in 0..=(rng.next_u64() % 3) {
                    let i = (rng.next_u64() as usize) % bytes.len();
                    bytes[i] = (rng.next_u64() & 0xff) as u8;
                }
            }
            // Garbage content-length value.
            2 => {
                let text = String::from_utf8(bytes).unwrap();
                bytes = text.replacen("Content-Length: ", "Content-Length: 12x", 1).into_bytes();
            }
            // Oversized content-length (past the body cap).
            3 => {
                let text = String::from_utf8(bytes).unwrap();
                let start = text.find("Content-Length: ").unwrap();
                let end = start + text[start..].find("\r\n").unwrap();
                let mut t = text.clone();
                t.replace_range(start..end, "Content-Length: 99999999");
                bytes = t.into_bytes();
            }
            // Invalid UTF-8 injected into the JSON body.
            4 => {
                let i = bytes.len() - 1 - ((rng.next_u64() as usize) % 100);
                bytes[i] = 0xff;
            }
            // Header-section garbage: a line with no colon.
            5 => {
                let text = String::from_utf8(bytes).unwrap();
                bytes = text.replacen("\r\n\r\n", "\r\nGARBAGE LINE\r\n\r\n", 1).into_bytes();
            }
            // Control bytes spliced into the request line.
            6 => {
                let i = (rng.next_u64() as usize) % 12;
                bytes[i] = (rng.next_u64() % 0x20) as u8;
            }
            // No mutation: the valid request must still serve under
            // whatever read-boundary split this round draws.
            _ => {}
        }
        let responses = stack.serve(random_split(&mut rng, &bytes));
        assert!(
            responses.len() <= 2,
            "case {case}: more responses than requests: {responses:?}"
        );
        for (status, body) in &responses {
            assert!(
                matches!(status, 200 | 400 | 404 | 405 | 411 | 413 | 431 | 500 | 503 | 504),
                "case {case}: undocumented status {status}: {body}"
            );
            // Every body — success or error — must be valid JSON.
            Json::parse(body)
                .unwrap_or_else(|e| panic!("case {case}: non-JSON body ({e}): {body}"));
            *status_seen.entry(*status).or_default() += 1;
        }
    }
    // The mutation set must actually exercise the error space, not
    // collapse into one rejection path.
    assert!(status_seen.contains_key(&200), "no 200s seen: {status_seen:?}");
    assert!(status_seen.contains_key(&400), "no 400s seen: {status_seen:?}");
    assert!(status_seen.contains_key(&413), "no 413s seen: {status_seen:?}");
    stack.coord.shutdown();
}

/// A valid request delivered one byte per `read()` call still parses and
/// serves (the scanner/framing layer holds no per-read state assumptions).
#[test]
fn single_byte_reads_still_serve() {
    let stack = Stack::start();
    let valid = http_common::format_request("POST", "/v1/infer", &infer_body("lenet"));
    let chunks: Vec<Vec<u8>> = valid.iter().map(|&b| vec![b]).collect();
    let responses = stack.serve(chunks);
    assert_eq!(responses.len(), 1, "{responses:?}");
    assert_eq!(responses[0].0, 200, "{responses:?}");
    stack.coord.shutdown();
}

/// Connection reuse after a semantic error: a framed-but-malformed JSON
/// body answers 400, then a good request on the SAME connection answers
/// 200 — the error must not poison the connection or leak parser state
/// into the next request.
#[test]
fn connection_survives_bad_request_then_serves_good_one() {
    let stack = Stack::start();
    let mut bytes = http_common::format_request("POST", "/v1/infer", "{\"image\":[1,2,");
    let good = http_common::format_request("POST", "/v1/infer", &infer_body("lenet"));
    bytes.extend_from_slice(&good);
    let responses = stack.serve(vec![bytes]);
    assert_eq!(responses.len(), 2, "{responses:?}");
    assert_eq!(responses[0].0, 400, "{responses:?}");
    assert_eq!(responses[1].0, 200, "{responses:?}");
    stack.coord.shutdown();
}

/// Pipelining: two complete requests in one read chunk get exactly two
/// responses, in order.
#[test]
fn pipelined_requests_answer_in_order() {
    let stack = Stack::start();
    let mut bytes = http_common::format_request("POST", "/v1/infer", &infer_body("lenet"));
    bytes.extend_from_slice(&http_common::format_request("POST", "/v1/infer", &infer_body("nope")));
    let responses = stack.serve(vec![bytes]);
    assert_eq!(responses.len(), 2, "{responses:?}");
    assert_eq!(responses[0].0, 200, "{responses:?}");
    assert_eq!(responses[1].0, 404, "{responses:?}");
    stack.coord.shutdown();
}
