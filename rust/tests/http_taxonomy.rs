//! Wire-level error-taxonomy conformance: one test per [`ServeError`]
//! variant, pinning the HTTP status code AND the JSON error body each
//! maps to. This file is the executable form of the README taxonomy
//! table's status column — change a mapping and exactly one test here
//! names the variant you broke.
//!
//! Self-contained synthetic weights throughout; every server binds port 0.

mod http_common;

use std::sync::Arc;
use std::time::Duration;

use http_common::{image_json, infer_body, request, serve_in_memory, TestServer};
use tpu_imac::coordinator::{
    Coordinator, CoordinatorConfig, FaultPlan, ModelRegistry, NativeBackend,
};
use tpu_imac::deploy::DeploymentSpec;
use tpu_imac::nn::synthetic::lenet_weights_doc;
use tpu_imac::serve_http::router::CoordinatorApp;
use tpu_imac::util::rng::Xoshiro256;

fn lenet_spec(seed: u64) -> DeploymentSpec {
    let mut rng = Xoshiro256::seed_from_u64(seed);
    DeploymentSpec::doc("lenet", lenet_weights_doc(&mut rng))
}

/// `UnknownModel` → 404, body names the bogus model and the registered
/// set (the variant's `Display` contract).
#[test]
fn unknown_model_is_404() {
    let ts = TestServer::start(CoordinatorConfig::default(), &[lenet_spec(1)]);
    let r = request(ts.addr, "POST", "/v1/infer", &infer_body("nope"));
    assert_eq!(r.status, 404, "{r:?}");
    assert_eq!(r.error_code(), "UnknownModel");
    assert_eq!(r.message(), "unknown model 'nope' (registered: lenet)");
    ts.shutdown();
}

/// `DeadlineExceeded` → 504: a zero budget is dead on arrival, answered
/// deterministically from the deadline guard (never executed).
#[test]
fn deadline_exceeded_is_504() {
    let ts = TestServer::start(CoordinatorConfig::default(), &[lenet_spec(2)]);
    let body = format!("{{\"model\":\"lenet\",\"image\":{},\"timeout_ms\":0}}", image_json());
    let r = request(ts.addr, "POST", "/v1/infer", &body);
    assert_eq!(r.status, 504, "{r:?}");
    assert_eq!(r.error_code(), "DeadlineExceeded");
    assert_eq!(r.message(), "deadline exceeded after 0us in queue");
    ts.shutdown();
}

/// `Draining` → 503: the coordinator has shut down but the front door is
/// still up — requests are refused at admission, not hung.
#[test]
fn draining_is_503() {
    let ts = TestServer::start(CoordinatorConfig::default(), &[lenet_spec(3)]);
    let TestServer { coord, server, addr, .. } = ts;
    coord.shutdown();
    let r = request(addr, "POST", "/v1/infer", &infer_body("lenet"));
    assert_eq!(r.status, 503, "{r:?}");
    assert_eq!(r.error_code(), "Draining");
    assert_eq!(r.message(), "coordinator is draining (shutdown in progress)");
    server.shutdown();
}

/// `WorkerFault` → 500: every batch panics (injected), the supervisor
/// restarts the worker, and the request is answered with the fault.
#[test]
fn worker_fault_is_500() {
    let spec =
        lenet_spec(4).faults(FaultPlan { seed: 1, panic_every: Some(1), ..Default::default() });
    let ts = TestServer::start(CoordinatorConfig::default(), &[spec]);
    let r = request(ts.addr, "POST", "/v1/infer", &infer_body("lenet"));
    assert_eq!(r.status, 500, "{r:?}");
    assert_eq!(r.error_code(), "WorkerFault");
    assert!(r.message().starts_with("worker fault serving model 'lenet'"), "{r:?}");
    ts.shutdown();
}

/// `NumericFault` → 500: the output-sanity guard refuses injected NaN
/// scores.
#[test]
fn numeric_fault_is_500() {
    let spec =
        lenet_spec(5).faults(FaultPlan { seed: 1, nan_every: Some(1), ..Default::default() });
    let ts = TestServer::start(CoordinatorConfig::default(), &[spec]);
    let r = request(ts.addr, "POST", "/v1/infer", &infer_body("lenet"));
    assert_eq!(r.status, 500, "{r:?}");
    assert_eq!(r.error_code(), "NumericFault");
    assert_eq!(r.message(), "model 'lenet' produced non-finite scores (numeric fault)");
    ts.shutdown();
}

/// `ShedLoad` → 429: per-model admission quota. One slow in-flight batch
/// (injected), one queued request filling the quota — the third submit is
/// shed at admission while the first two still get answered.
#[test]
fn shed_load_is_429() {
    let spec = lenet_spec(6)
        .queue_quota(1)
        .faults(FaultPlan { seed: 1, slow_every: Some(1), slow_us: 300_000, ..Default::default() });
    let config = CoordinatorConfig { max_batch: 1, workers: 1, ..Default::default() };
    let ts = TestServer::start(config, &[spec]);
    // Generous per-request budgets so A and B never trip the deadline
    // guard — this test isolates the quota.
    let body = format!("{{\"model\":\"lenet\",\"image\":{},\"timeout_ms\":10000}}", image_json());
    let slow = |addr, body: String| {
        std::thread::spawn(move || request(addr, "POST", "/v1/infer", &body))
    };
    let a = slow(ts.addr, body.clone()); // dequeued by the (slow) worker
    std::thread::sleep(Duration::from_millis(120));
    let b = slow(ts.addr, body.clone()); // queued: fills quota 1
    std::thread::sleep(Duration::from_millis(60));
    let r = request(ts.addr, "POST", "/v1/infer", &body); // over quota
    assert_eq!(r.status, 429, "{r:?}");
    assert_eq!(r.error_code(), "ShedLoad");
    assert_eq!(r.message(), "load shed for model 'lenet': 1 queued >= quota 1");
    for handle in [a, b] {
        let r = handle.join().expect("request thread");
        assert!(r.status == 200 || r.status == 504, "shed must not lose replies: {r:?}");
    }
    ts.shutdown();
}

/// `QueueFull` → 503: whole-queue backpressure (checked before the
/// per-model quota). Same slow-worker shape as the shed test but with the
/// global queue capped at 1 and no quota.
#[test]
fn queue_full_is_503() {
    let spec = lenet_spec(7)
        .faults(FaultPlan { seed: 1, slow_every: Some(1), slow_us: 300_000, ..Default::default() });
    let config =
        CoordinatorConfig { max_batch: 1, workers: 1, max_queue: 1, ..Default::default() };
    let ts = TestServer::start(config, &[spec]);
    let body = format!("{{\"model\":\"lenet\",\"image\":{},\"timeout_ms\":10000}}", image_json());
    let slow = |addr, body: String| {
        std::thread::spawn(move || request(addr, "POST", "/v1/infer", &body))
    };
    let a = slow(ts.addr, body.clone()); // in flight
    std::thread::sleep(Duration::from_millis(120));
    let b = slow(ts.addr, body.clone()); // occupies the 1-deep queue
    std::thread::sleep(Duration::from_millis(60));
    let r = request(ts.addr, "POST", "/v1/infer", &body);
    assert_eq!(r.status, 503, "{r:?}");
    assert_eq!(r.error_code(), "QueueFull");
    assert_eq!(r.message(), "queue full (1 requests)");
    for handle in [a, b] {
        let r = handle.join().expect("request thread");
        assert!(r.status == 200 || r.status == 504, "backpressure must not lose replies: {r:?}");
    }
    ts.shutdown();
}

/// `NoRegistry` → 500: a routed submit against a *fixed-backend*
/// coordinator (`Coordinator::start`, no registry wired into its client).
/// `TestServer` cannot reach this variant — `start_registry` refuses an
/// empty registry — so it drives the production [`CoordinatorApp`]
/// through the real framing layer over an in-memory stream: same request
/// bytes, same response bytes, minus the socket.
#[test]
fn no_registry_is_500() {
    let dep = lenet_spec(9).build().expect("build deployment");
    let model = Arc::clone(&dep.model);
    let coord = Coordinator::start(
        CoordinatorConfig::default(),
        move || Box::new(NativeBackend::new(model)),
    );
    // The app's registry can resolve the name — the coordinator behind it
    // cannot: that mismatch is exactly what this variant reports.
    let registry = Arc::new(ModelRegistry::new());
    registry.register_built(dep).expect("register");
    let mut app = CoordinatorApp::new(
        coord.client(),
        registry,
        Arc::clone(&coord.metrics),
        1000,
        "artifacts".to_string(),
    );
    let req = http_common::format_request("POST", "/v1/infer", &infer_body("lenet"));
    let r = serve_in_memory(&mut app, &req);
    assert_eq!(r.status, 500, "{r:?}");
    assert_eq!(r.error_code(), "NoRegistry");
    assert_eq!(
        r.message(),
        "this coordinator serves a single fixed backend (no model registry)"
    );
    coord.shutdown();
}

/// The non-error side of the contract: a well-formed infer on a healthy
/// server is a 200 whose body carries id/predicted/latency_us/scores.
#[test]
fn healthy_infer_is_200_with_scores() {
    let ts = TestServer::start(CoordinatorConfig::default(), &[lenet_spec(8)]);
    let r = request(ts.addr, "POST", "/v1/infer", &infer_body("lenet"));
    assert_eq!(r.status, 200, "{r:?}");
    let doc = r.json();
    assert!(doc.get("id").as_f64().is_some(), "{r:?}");
    let predicted = doc.get("predicted").as_f64().expect("predicted");
    assert!((0.0..10.0).contains(&predicted), "{r:?}");
    let scores = doc.get("scores").as_f64_vec().expect("scores");
    assert_eq!(scores.len(), 10);
    assert!(scores.iter().all(|s| s.is_finite()));
    ts.shutdown();
}
