//! Architecture-level invariants over the whole zoo + randomized models:
//! scheduling, memory accounting, bridge constraints, IMAC fabric
//! equivalences. These pin the claims the paper's tables rest on.

use tpu_imac::arch::{self, Mode};
use tpu_imac::arch::memory::MemoryFootprint;
use tpu_imac::imac::{AdcConfig, ImacConfig, ImacFabric};
use tpu_imac::systolic::{ArrayConfig, SramConfig};
use tpu_imac::util::prop::{forall, Gen};
use tpu_imac::workload::{zoo, Dataset, ModelBuilder};

#[test]
fn speedup_equals_cycle_ratio_and_exceeds_one() {
    let cfg = ArrayConfig::default();
    let sram = SramConfig::default();
    for m in zoo::paper_suite() {
        let e = arch::evaluate(&m, &cfg, &sram).unwrap();
        assert!(e.speedup() > 1.0, "{}", m.name);
        assert!(
            (e.speedup() - e.cycles_tpu as f64 / e.cycles_hybrid as f64).abs() < 1e-12
        );
    }
}

#[test]
fn amdahl_consistency() {
    // Paper §6: improvements "follow Amdahl's law ... proportional to the
    // ratio of FC to convolutional layers". Check: speedup == 1 / (1 - f +
    // f*s) with f the FC cycle fraction and s the per-FC-cycle speedup
    // implied by our own numbers — i.e. internal consistency of the split.
    let cfg = ArrayConfig::default();
    let sram = SramConfig::default();
    for m in zoo::paper_suite() {
        let tpu = arch::schedule(&m, &cfg, &sram, Mode::TpuOnly).unwrap();
        let hyb = arch::schedule(&m, &cfg, &sram, Mode::TpuImac).unwrap();
        let conv_cycles = hyb.systolic_cycles;
        let fc_cycles_tpu = tpu.total_cycles - conv_cycles;
        assert_eq!(
            hyb.total_cycles,
            conv_cycles + hyb.imac_cycles,
            "{}: hybrid must be conv + 1/layer",
            m.name
        );
        assert_eq!(hyb.imac_cycles as usize, m.dense_layers().len());
        assert!(fc_cycles_tpu > 0);
    }
}

#[test]
fn memory_model_identities() {
    forall(30, |g: &mut Gen| {
        // Random small CNN: conv stack + FC head.
        let mut b = ModelBuilder::new("rand", Dataset::Cifar10);
        let c1 = g.usize_in(4, 32);
        b.conv(3, c1, 1, 1).relu().maxpool(2, 2);
        b.conv(3, g.usize_in(4, 64), 2, 1).relu();
        b.global_avgpool().flatten();
        b.dense(g.usize_in(4, 128));
        b.dense(10);
        let m = b.build();
        let f = MemoryFootprint::of(&m);
        // Identity: TPU bytes = SRAM + FC fp32 bytes.
        let fc_fp32 = (m.fc_weight_params() + m.fc_bias_params()) * 4;
        assert_eq!(f.tpu_bytes, f.hybrid_sram_bytes + fc_fp32);
        // RRAM = 2 bits per FC weight.
        assert_eq!(f.hybrid_rram_bytes, (2 * m.fc_weight_params()).div_ceil(8));
        // int8 conv deployment: weights 1 B + (bias + requantize scale)
        // 4 B each per channel, strictly below the fp32 SRAM share;
        // reduction strictly improves.
        assert_eq!(
            f.hybrid_int8_sram_bytes,
            m.conv_weight_params() + 8 * m.conv_bias_params()
        );
        assert!(f.hybrid_int8_sram_bytes < f.hybrid_sram_bytes);
        assert!(f.int8_reduction() > f.reduction());
        // Reduction in (0, 1).
        let r = f.reduction();
        assert!(r > 0.0 && r < 1.0, "r={r}");
    });
}

#[test]
fn bridge_wider_than_array_is_rejected() {
    let mut b = ModelBuilder::new("wide", Dataset::Cifar10);
    b.conv(3, 8, 1, 1); // 32x32x8 = 8192 flatten > 1024 PEs
    b.flatten();
    b.dense(10);
    let m = b.build();
    let cfg = ArrayConfig::default();
    let sram = SramConfig::default();
    assert!(arch::schedule(&m, &cfg, &sram, Mode::TpuImac).is_err());
    // A larger array accepts it.
    let big = ArrayConfig { rows: 128, cols: 128, ..ArrayConfig::default() };
    assert!(arch::schedule(&m, &big, &sram, Mode::TpuImac).is_ok());
}

#[test]
fn imac_fabric_matches_scalar_reference() {
    // The fabric (partitioned, gain, sigmoid, chained) must equal a direct
    // scalar evaluation of sigmoid(gain * W^T x) layer by layer.
    forall(15, |g: &mut Gen| {
        let n0 = g.usize_in(1, 300);
        let n1 = g.usize_in(1, 50);
        let n2 = g.usize_in(1, 12);
        let w1 = g.vec_ternary(n0 * n1);
        let w2 = g.vec_ternary(n1 * n2);
        let x: Vec<f32> = g.vec_sign(n0).iter().map(|&s| s as f32).collect();
        let cfg = ImacConfig { subarray_rows: 64, subarray_cols: 32, ..Default::default() };
        let fabric = ImacFabric::build(
            &[(w1.clone(), n0, n1), (w2.clone(), n1, n2)],
            &cfg,
            AdcConfig { bits: 0, full_scale: 1.0 },
            0,
        );
        let got = fabric.forward(&x);

        let layer = |x: &[f32], w: &[i8], n_in: usize, n_out: usize| -> Vec<f32> {
            let gain = cfg.amp_gain(n_in) as f32;
            (0..n_out)
                .map(|j| {
                    let pre: f32 =
                        (0..n_in).map(|i| x[i] * w[i * n_out + j] as f32).sum::<f32>() * gain;
                    1.0 / (1.0 + (-pre).exp())
                })
                .collect()
        };
        let h1 = layer(&x, &w1, n0, n1);
        let want = layer(&h1, &w2, n1, n2);
        for (a, b) in got.iter().zip(&want) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    });
}

#[test]
fn adc_bits_only_quantize_do_not_reorder_strongly() {
    // With 8-bit ADC the argmax of well-separated outputs must not change.
    forall(20, |g: &mut Gen| {
        let n0 = 64;
        let n1 = 10;
        let w = g.vec_ternary(n0 * n1);
        let x: Vec<f32> = g.vec_sign(n0).iter().map(|&s| s as f32).collect();
        let mk = |bits: u32| {
            ImacFabric::build(
                &[(w.clone(), n0, n1)],
                &ImacConfig::default(),
                AdcConfig { bits, full_scale: 1.0 },
                0,
            )
        };
        let ideal = mk(0).forward(&x);
        let quant = mk(8).forward(&x);
        let am = tpu_imac::util::stats::argmax(&ideal);
        // Only assert when the winner is clear by more than one LSB (1/255).
        let mut sorted = ideal.clone();
        sorted.sort_by(|a, b| b.partial_cmp(a).unwrap());
        if sorted[0] - sorted[1] > 2.0 / 255.0 {
            assert_eq!(tpu_imac::util::stats::argmax(&quant), am);
        }
    });
}
