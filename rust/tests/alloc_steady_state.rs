//! Counting-allocator proof of the zero-allocation inference hot path:
//! after warmup, the GEMM conv plan + bridge + IMAC fabric must perform
//! **zero** heap allocations per image (the scratch arena is fully grown
//! and every buffer is reused) — on the fp32 path, the dynamic int8 path
//! AND the calibrated int8 path (whose i8 staging and i32 accumulator
//! buffers live in the same arena), on both a plain conv stack (LeNet)
//! and a depthwise MobileNet-style stack exercising the DwI8 kernel.
//! Calibrated plans must additionally perform **zero** per-image max-abs
//! scans (`Scratch::maxabs_scans` stays 0 — the scan is gone from the
//! steady state, not merely cheap).
//!
//! Since the bit-sliced FC hot path landed, both `infer_into` and
//! `infer_batch_into` drive the whole FC section batch-at-a-time through
//! `ImacFabric::forward_batch_into` — layer-1 popcount bitplanes staged
//! in `Scratch::fc_bits`, later layers through the cache-blocked batched
//! analog MVM — so the zero-alloc budget below covers the batched FC
//! path (and its sign-bitmask staging) across every deployment shape.
//!
//! This file contains exactly one test so no concurrent test thread can
//! pollute the global allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tpu_imac::imac::{AdcConfig, ImacConfig};
use tpu_imac::nn::synthetic::{lenet_weights_doc, mobilenet_mini_weights_doc};
use tpu_imac::nn::{DeployedModel, PrecisionPolicy, Scratch, Tensor};
use tpu_imac::quant::calibrate_conv_ops;
use tpu_imac::util::rng::Xoshiro256;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_inference_allocates_nothing() {
    let mut rng = Xoshiro256::seed_from_u64(99);
    let images: Vec<Tensor> = (0..8)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();
    let refs: Vec<&Tensor> = images.iter().collect();

    // (model doc, quantized-layer count) — LeNet pins the plain conv
    // stack, the MobileNet-mini stack adds DwI8 depthwise layers.
    let docs =
        [(lenet_weights_doc(&mut rng), 2u64), (mobilenet_mini_weights_doc(&mut rng), 5u64)];
    for (doc, i8_layers) in &docs {
        // Calibration happens offline (allocates freely, outside the
        // counted region), like `tpu-imac calibrate`.
        let oracle = DeployedModel::from_json(
            doc,
            &ImacConfig::default(),
            AdcConfig { bits: 0, full_scale: 1.0 },
            0,
        )
        .unwrap();
        let table = calibrate_conv_ops(&oracle.conv_ops, &images, 100.0).unwrap();

        for (precision, calibrated) in [
            (PrecisionPolicy::Fp32, false),
            (PrecisionPolicy::Int8, false),
            (PrecisionPolicy::Int8, true),
        ] {
            let model = DeployedModel::from_json_calibrated(
                doc,
                &ImacConfig::default(),
                AdcConfig { bits: 0, full_scale: 1.0 },
                0,
                precision,
                if calibrated { Some(&table) } else { None },
            )
            .unwrap();
            let mut scratch = Scratch::new();

            // Warmup: grow the arena to the workload's high-water mark
            // (single image AND batch shapes — the batch is the larger
            // footprint).
            let mut sum = 0.0f32;
            for img in &images {
                sum += model.infer_into(img, &mut scratch)[0];
            }
            model.infer_batch_into(&refs, &mut scratch, |_, scores| sum += scores[0]);
            let warm_grows = scratch.grow_events;
            assert!(warm_grows > 0, "warmup should have grown the arena");
            assert!(
                scratch.fc_bits.capacity() > 0,
                "the bit-sliced FC path must have staged sign bitmasks during warmup"
            );
            let warm_scans = scratch.maxabs_scans;

            // Steady state: count every heap allocation across
            // single-image and batched inference. Must be exactly zero,
            // in every precision/calibration combination.
            let before = ALLOCS.load(Ordering::SeqCst);
            for _ in 0..3 {
                for img in &images {
                    sum += model.infer_into(img, &mut scratch)[0];
                }
                model.infer_batch_into(&refs, &mut scratch, |_, scores| sum += scores[0]);
            }
            let delta = ALLOCS.load(Ordering::SeqCst) - before;
            assert!(sum.is_finite());
            let label = format!(
                "{}{}",
                precision.label(),
                if calibrated { "+calibrated" } else { "" }
            );
            assert_eq!(
                delta, 0,
                "steady-state {label} request path performed {delta} heap allocations (want 0)"
            );
            assert_eq!(
                scratch.grow_events, warm_grows,
                "{label} scratch arena regrew at steady state"
            );
            // The max-abs pass: gone entirely under calibration, one per
            // image per quantized layer otherwise (48 images steady-state:
            // 3 rounds × (8 single + 8 batched)).
            let steady_scans = scratch.maxabs_scans - warm_scans;
            match (precision, calibrated) {
                (PrecisionPolicy::Fp32, _) => {
                    assert_eq!(scratch.maxabs_scans, 0, "fp32 plan never scans")
                }
                (PrecisionPolicy::Int8, true) => assert_eq!(
                    scratch.maxabs_scans, 0,
                    "calibrated int8 plan must not scan activation ranges"
                ),
                (PrecisionPolicy::Int8, false) => assert_eq!(
                    steady_scans,
                    48 * i8_layers,
                    "dynamic int8 plan scans once per image per quantized layer"
                ),
            }
        }
    }
}
