//! Counting-allocator proof of the zero-allocation inference hot path:
//! after warmup, the GEMM conv plan + bridge + IMAC fabric must perform
//! **zero** heap allocations per image (the scratch arena is fully grown
//! and every buffer is reused) — on the fp32 path AND the int8 quantized
//! path (whose i8 staging and i32 accumulator buffers live in the same
//! arena).
//!
//! This file contains exactly one test so no concurrent test thread can
//! pollute the global allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tpu_imac::imac::{AdcConfig, ImacConfig};
use tpu_imac::nn::synthetic::lenet_weights_doc;
use tpu_imac::nn::{DeployedModel, PrecisionPolicy, Scratch, Tensor};
use tpu_imac::util::rng::Xoshiro256;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_inference_allocates_nothing() {
    let mut rng = Xoshiro256::seed_from_u64(99);
    let doc = lenet_weights_doc(&mut rng);
    let images: Vec<Tensor> = (0..8)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();
    let refs: Vec<&Tensor> = images.iter().collect();

    for precision in [PrecisionPolicy::Fp32, PrecisionPolicy::Int8] {
        let model = DeployedModel::from_json_with(
            &doc,
            &ImacConfig::default(),
            AdcConfig { bits: 0, full_scale: 1.0 },
            0,
            precision,
        )
        .unwrap();
        let mut scratch = Scratch::new();

        // Warmup: grow the arena to the workload's high-water mark (single
        // image AND batch shapes — the batch is the larger footprint).
        let mut sum = 0.0f32;
        for img in &images {
            sum += model.infer_into(img, &mut scratch)[0];
        }
        model.infer_batch_into(&refs, &mut scratch, |_, scores| sum += scores[0]);
        let warm_grows = scratch.grow_events;
        assert!(warm_grows > 0, "warmup should have grown the arena");

        // Steady state: count every heap allocation across single-image and
        // batched inference. Must be exactly zero, in either precision.
        let before = ALLOCS.load(Ordering::SeqCst);
        for _ in 0..3 {
            for img in &images {
                sum += model.infer_into(img, &mut scratch)[0];
            }
            model.infer_batch_into(&refs, &mut scratch, |_, scores| sum += scores[0]);
        }
        let delta = ALLOCS.load(Ordering::SeqCst) - before;
        assert!(sum.is_finite());
        assert_eq!(
            delta,
            0,
            "steady-state {} request path performed {delta} heap allocations (want 0)",
            precision.label()
        );
        assert_eq!(
            scratch.grow_events, warm_grows,
            "{} scratch arena regrew at steady state",
            precision.label()
        );
    }
}
