//! Counting-allocator proof of the zero-allocation inference hot path:
//! after warmup, the GEMM conv plan + bridge + IMAC fabric must perform
//! **zero** heap allocations per image (the scratch arena is fully grown
//! and every buffer is reused) — on the fp32 path, the dynamic int8 path
//! AND the calibrated int8 path (whose i8 staging and i32 accumulator
//! buffers live in the same arena), on both a plain conv stack (LeNet)
//! and a depthwise MobileNet-style stack exercising the DwI8 kernel.
//! Calibrated plans must additionally perform **zero** per-image max-abs
//! scans (`ConvScratch::maxabs_scans` stays 0 — the scan is gone from the
//! steady state, not merely cheap).
//!
//! Deployments are built through `DeploymentSpec` (the same front door the
//! serving registry uses), and the suite additionally covers:
//!
//! * a **two-deployment `ModelRegistry`** (fp32 LeNet + int8 dw-stack):
//!   per-batch slot resolution plus interleaved inference through
//!   per-model scratch arenas stays allocation-free at steady state — the
//!   registry request path adds no heap traffic of its own;
//! * the **PJRT pack buffer** (`Scratch::pack_images`): staging a chunk
//!   into the fixed artifact batch reuses the arena's pack buffer instead
//!   of allocating per chunk;
//! * the **SIMD-dispatched FC kernels** (PR 7): a non-ideal deployment
//!   (batched analog micro-kernel + per-row batch tail) and a 2-bit
//!   bridge deployment (multi-plane popcount layer 1) — runtime dispatch
//!   and autotuned tiling add no heap traffic.
//!
//! Since the bit-sliced FC hot path landed, both `infer_into` and
//! `infer_batch_into` drive the whole FC section batch-at-a-time through
//! `ImacFabric::forward_batch_into` — layer-1 popcount bitplanes staged
//! in `FcScratch::bits`, later layers through the cache-blocked batched
//! analog MVM — so the zero-alloc budget below covers the batched FC
//! path (and its sign-bitmask staging) across every deployment shape.
//!
//! The HTTP front-end's wire layer has the same discipline, pinned by its
//! own single-test counting-allocator suite
//! (`tests/alloc_http_steady_state.rs`): a warmed persistent connection
//! serves `POST /v1/infer` — framing, body scan, response formatting —
//! with zero allocations on top of the in-process request path this file
//! covers.
//!
//! This file contains exactly one test so no concurrent test thread can
//! pollute the global allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

use tpu_imac::coordinator::ModelRegistry;
use tpu_imac::deploy::DeploymentSpec;
use tpu_imac::nn::synthetic::{lenet_weights_doc, mobilenet_mini_weights_doc};
use tpu_imac::nn::{PrecisionPolicy, Scratch, Tensor};
use tpu_imac::quant::calibrate_conv_ops;
use tpu_imac::util::rng::Xoshiro256;

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to `System` — every method forwards its exact
// arguments, so `System`'s layout/pointer contract is preserved verbatim;
// the only extra work is a Relaxed counter bump, which cannot allocate.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds `GlobalAlloc::alloc`'s layout contract; we
    // forward it unchanged to `System`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    // SAFETY: `ptr`/`layout` come from a matching `alloc` on `System`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    // SAFETY: caller upholds `realloc`'s contract; forwarded unchanged.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    // SAFETY: caller upholds `alloc_zeroed`'s contract; forwarded unchanged.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

#[test]
fn steady_state_inference_allocates_nothing() {
    let mut rng = Xoshiro256::seed_from_u64(99);
    let images: Vec<Tensor> = (0..8)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();
    let refs: Vec<&Tensor> = images.iter().collect();

    // (model doc, quantized-layer count) — LeNet pins the plain conv
    // stack, the MobileNet-mini stack adds DwI8 depthwise layers.
    let docs =
        [(lenet_weights_doc(&mut rng), 2u64), (mobilenet_mini_weights_doc(&mut rng), 5u64)];
    for (doc, i8_layers) in &docs {
        // Calibration happens offline (allocates freely, outside the
        // counted region), like `tpu-imac calibrate`.
        let oracle = DeploymentSpec::doc("oracle", doc.clone()).build().unwrap().model;
        let table = calibrate_conv_ops(&oracle.conv_ops, &images, 100.0).unwrap();

        for (precision, calibrated) in [
            (PrecisionPolicy::Fp32, false),
            (PrecisionPolicy::Int8, false),
            (PrecisionPolicy::Int8, true),
        ] {
            let mut spec = DeploymentSpec::doc("m", doc.clone()).precision(precision);
            if calibrated {
                spec = spec.calibration_table(table.clone());
            }
            let model = spec.build().unwrap().model;
            let mut scratch = Scratch::new();

            // Warmup: grow the arena to the workload's high-water mark
            // (single image AND batch shapes — the batch is the larger
            // footprint).
            let mut sum = 0.0f32;
            for img in &images {
                sum += model.infer_into(img, &mut scratch)[0];
            }
            model.infer_batch_into(&refs, &mut scratch, |_, scores| sum += scores[0]);
            let warm_grows = scratch.grow_events();
            assert!(warm_grows > 0, "warmup should have grown the arena");
            assert!(
                scratch.fc.bits.capacity() > 0,
                "the bit-sliced FC path must have staged sign bitmasks during warmup"
            );
            let warm_scans = scratch.maxabs_scans();

            // Steady state: count every heap allocation across
            // single-image and batched inference. Must be exactly zero,
            // in every precision/calibration combination.
            let before = ALLOCS.load(Ordering::SeqCst);
            for _ in 0..3 {
                for img in &images {
                    sum += model.infer_into(img, &mut scratch)[0];
                }
                model.infer_batch_into(&refs, &mut scratch, |_, scores| sum += scores[0]);
            }
            let delta = ALLOCS.load(Ordering::SeqCst) - before;
            assert!(sum.is_finite());
            let label = format!(
                "{}{}",
                precision.label(),
                if calibrated { "+calibrated" } else { "" }
            );
            assert_eq!(
                delta, 0,
                "steady-state {label} request path performed {delta} heap allocations (want 0)"
            );
            assert_eq!(
                scratch.grow_events(),
                warm_grows,
                "{label} scratch arena regrew at steady state"
            );
            // The max-abs pass: gone entirely under calibration, one per
            // image per quantized layer otherwise (48 images steady-state:
            // 3 rounds × (8 single + 8 batched)).
            let steady_scans = scratch.maxabs_scans() - warm_scans;
            match (precision, calibrated) {
                (PrecisionPolicy::Fp32, _) => {
                    assert_eq!(scratch.maxabs_scans(), 0, "fp32 plan never scans")
                }
                (PrecisionPolicy::Int8, true) => assert_eq!(
                    scratch.maxabs_scans(),
                    0,
                    "calibrated int8 plan must not scan activation ranges"
                ),
                (PrecisionPolicy::Int8, false) => assert_eq!(
                    steady_scans,
                    48 * i8_layers,
                    "dynamic int8 plan scans once per image per quantized layer"
                ),
            }
        }
    }

    // The PR-7 FC kernels share the budget: a non-ideal deployment (the
    // cache-blocked batched analog micro-kernel + per-row batch tail) and
    // a 2-bit-bridge deployment (multi-plane popcount layer 1 with the
    // in-place level quantizer) must also serve with zero steady-state
    // allocations — SIMD dispatch and tiling never touch the heap. The
    // 5-image batch exercises the `nimg % 4` tail path explicitly.
    {
        use tpu_imac::imac::{CrossbarConfig, DeviceConfig, ImacConfig};
        let noisy = ImacConfig {
            crossbar: CrossbarConfig {
                device: DeviceConfig { sigma: 0.05, ..Default::default() },
                wire_alpha: 0.02,
                amp_offset_sigma: 0.01,
            },
            ..Default::default()
        };
        let multibit = ImacConfig { bridge_bits: 2, bridge_full_scale: 2.0, ..Default::default() };
        for (imac, label) in [(noisy, "non-ideal analog-batch"), (multibit, "2-bit bridge")] {
            let model = DeploymentSpec::doc("m", docs[0].0.clone())
                .imac(imac)
                .fabric_seed(7)
                .build()
                .unwrap()
                .model;
            let mut scratch = Scratch::new();
            let mut sum = 0.0f32;
            for img in &images {
                sum += model.infer_into(img, &mut scratch)[0];
            }
            model.infer_batch_into(&refs, &mut scratch, |_, scores| sum += scores[0]);
            model.infer_batch_into(&refs[..5], &mut scratch, |_, scores| sum += scores[0]);
            let warm_grows = scratch.grow_events();
            let before = ALLOCS.load(Ordering::SeqCst);
            for _ in 0..3 {
                for img in &images {
                    sum += model.infer_into(img, &mut scratch)[0];
                }
                model.infer_batch_into(&refs, &mut scratch, |_, scores| sum += scores[0]);
                model.infer_batch_into(&refs[..5], &mut scratch, |_, scores| sum += scores[0]);
            }
            let delta = ALLOCS.load(Ordering::SeqCst) - before;
            assert!(sum.is_finite());
            assert_eq!(
                delta, 0,
                "steady-state {label} path performed {delta} heap allocations (want 0)"
            );
            assert_eq!(
                scratch.grow_events(),
                warm_grows,
                "{label} scratch arena regrew at steady state"
            );
        }
    }

    // Two-deployment registry: the multi-model request path — per-batch
    // slot resolution + per-model scratch arenas over Arc-shared plans —
    // must stay allocation-free at steady state too, across deployment
    // shapes (fp32 LeNet, int8 dw-stack) interleaved like mixed traffic.
    let registry = ModelRegistry::new();
    registry
        .register(&DeploymentSpec::doc("lenet", docs[0].0.clone()))
        .unwrap();
    registry
        .register(
            &DeploymentSpec::doc("mm", docs[1].0.clone()).precision(PrecisionPolicy::Int8),
        )
        .unwrap();
    assert_eq!(registry.slot("lenet"), Some(0));
    assert_eq!(registry.slot("mm"), Some(1));
    let mut scratches = [Scratch::new(), Scratch::new()];
    let mut sum = 0.0f32;
    // Warmup both per-model arenas through the resolved deployments.
    for slot in [0usize, 1, 0, 1] {
        let (_, dep) = registry.resolve(slot).unwrap();
        dep.model.infer_batch_into(&refs, &mut scratches[slot], |_, scores| sum += scores[0]);
    }
    let warm: u64 = scratches.iter().map(|s| s.grow_events()).sum();
    let before = ALLOCS.load(Ordering::SeqCst);
    for round in 0..6 {
        // Alternate models per "batch" exactly like interleaved traffic.
        let slot = round % 2;
        let (generation, dep) = registry.resolve(slot).unwrap();
        assert_eq!(generation, 1, "no swap happened");
        dep.model.infer_batch_into(&refs, &mut scratches[slot], |_, scores| sum += scores[0]);
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert!(sum.is_finite());
    assert_eq!(
        delta, 0,
        "steady-state 2-deployment registry path performed {delta} heap allocations (want 0)"
    );
    assert_eq!(
        scratches.iter().map(|s| s.grow_events()).sum::<u64>(),
        warm,
        "registry scratch arenas regrew at steady state"
    );

    // PJRT pack-buffer staging: packing a chunk into the fixed artifact
    // batch must reuse the arena's pack buffer (zero-padded tail), not
    // allocate per chunk.
    let mut scratch = Scratch::new();
    let _ = scratch.pack_images(&refs, 8, 784); // warmup
    let pack_grows = scratch.pack_grows;
    assert!(pack_grows > 0, "warmup should have grown the pack buffer");
    let before = ALLOCS.load(Ordering::SeqCst);
    for chunk in [&refs[..8], &refs[..3], &refs[..5]] {
        let block = scratch.pack_images(chunk, 8, 784);
        sum += block[0] + block[8 * 784 - 1];
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert!(sum.is_finite());
    assert_eq!(delta, 0, "steady-state pack staging performed {delta} heap allocations (want 0)");
    assert_eq!(scratch.pack_grows, pack_grows, "pack buffer regrew at steady state");
}
