//! Cross-validation of the two systolic models: the analytic cycle
//! expressions must agree with the register-level OS stepper, and the
//! stepper must compute correct GEMMs — the foundation under every cycle
//! number in the reproduced tables.

use tpu_imac::systolic::analytic::{simulate_gemm, ArrayConfig, Dataflow, FoldOverlap};
use tpu_imac::systolic::array::{naive_matmul, run_os_fold};
use tpu_imac::util::prop::{forall, Gen};
use tpu_imac::workload::GemmShape;

fn rand_mat(g: &mut Gen, r: usize, c: usize) -> Vec<Vec<f32>> {
    (0..r).map(|_| g.vec_f32(c, -1.5, 1.5)).collect()
}

#[test]
fn stepper_matches_analytic_single_fold_cycles() {
    // For a GEMM that fits in one fold, the conservative analytic per-fold
    // formula 2r+c+K-2 must equal the stepper's cycles including drain.
    forall(60, |g| {
        let r = g.usize_in(1, 16);
        let c = g.usize_in(1, 16);
        let k = g.usize_in(1, 24);
        let a = rand_mat(g, r, k);
        let b = rand_mat(g, k, c);
        let run = run_os_fold(&a, &b);
        let cfg = ArrayConfig {
            rows: r.max(1),
            cols: c.max(1),
            dataflow: Dataflow::Os,
            overlap: FoldOverlap::Conservative,
        };
        let s = simulate_gemm(&cfg, &GemmShape::new(r, k, c));
        assert_eq!(s.folds, 1);
        assert_eq!(s.cycles, run.cycles_with_drain, "r={r} c={c} k={k}");
    });
}

#[test]
fn stepper_output_is_the_gemm() {
    forall(40, |g| {
        let r = g.usize_in(1, 10);
        let c = g.usize_in(1, 10);
        let k = g.usize_in(1, 20);
        let a = rand_mat(g, r, k);
        let b = rand_mat(g, k, c);
        let run = run_os_fold(&a, &b);
        let want = naive_matmul(&a, &b);
        for i in 0..r {
            for j in 0..c {
                assert!((run.outputs[i][j] - want[i][j]).abs() < 1e-3);
            }
        }
        assert_eq!(run.total_macs, (r * c * k) as u64);
    });
}

#[test]
fn multi_fold_cycles_are_sum_of_fold_windows() {
    // Conservative multi-fold = sum over folds of single-fold formula.
    forall(40, |g| {
        let m = g.usize_in(1, 100);
        let n = g.usize_in(1, 100);
        let k = g.usize_in(1, 64);
        let cfg = ArrayConfig {
            rows: 32,
            cols: 32,
            dataflow: Dataflow::Os,
            overlap: FoldOverlap::Conservative,
        };
        let s = simulate_gemm(&cfg, &GemmShape::new(m, k, n));
        // Recompute by explicit fold enumeration.
        let mut want = 0u64;
        let fr = (m + 31) / 32;
        let fc = (n + 31) / 32;
        for ir in 0..fr {
            let r = if ir + 1 == fr { m - (fr - 1) * 32 } else { 32 };
            for ic in 0..fc {
                let c = if ic + 1 == fc { n - (fc - 1) * 32 } else { 32 };
                want += (2 * r + c + k - 2) as u64;
            }
        }
        assert_eq!(s.cycles, want, "m={m} n={n} k={k}");
    });
}

#[test]
fn pipelined_equals_fill_stream_drain() {
    forall(40, |g| {
        let m = g.usize_in(1, 200);
        let n = g.usize_in(1, 200);
        let k = g.usize_in(1, 64);
        let cfg = ArrayConfig::default();
        let s = simulate_gemm(&cfg, &GemmShape::new(m, k, n));
        let fr = (m + 31) / 32;
        let fc = (n + 31) / 32;
        let fill = (m.min(32) + n.min(32)).saturating_sub(2) as u64;
        let stream = (fr * fc * k) as u64;
        let drain = (m - (fr - 1) * 32) as u64;
        assert_eq!(s.cycles, fill + stream + drain, "m={m} n={n} k={k}");
    });
}

#[test]
fn utilization_inversely_tracks_padding_waste() {
    // A GEMM that exactly tiles the array must beat one that pads.
    let cfg = ArrayConfig::default();
    let exact = simulate_gemm(&cfg, &GemmShape::new(64, 128, 64));
    let padded = simulate_gemm(&cfg, &GemmShape::new(33, 128, 33)); // 1-wide remainders
    assert!(exact.mapping_efficiency > padded.mapping_efficiency);
    assert!(exact.utilization > padded.utilization);
}

#[test]
fn groups_scale_linearly() {
    let cfg = ArrayConfig::default();
    let g1 = simulate_gemm(&cfg, &GemmShape { m: 256, k: 9, n: 1, groups: 1 });
    let g32 = simulate_gemm(&cfg, &GemmShape { m: 256, k: 9, n: 1, groups: 32 });
    assert_eq!(g32.macs, 32 * g1.macs);
    // Pipelined: fill+drain paid once, stream scales with groups.
    // fill = min(32,256)+min(32,1)-2 = 31, drain = 32, stream = folds*K.
    assert_eq!(g1.cycles, 31 + 8 * 9 + 32);
    assert_eq!(g32.cycles, 31 + 32 * 8 * 9 + 32);
}
