//! Counting-allocator proof that the HTTP wire layer adds **zero** heap
//! allocations to the steady-state `POST /v1/infer` path on a warmed
//! persistent connection.
//!
//! Drives the real production stack — [`serve_connection`] framing, the
//! lazy single-pass [`scan_infer`] body scanner, and the
//! [`write_infer_response`] formatter — over an in-memory persistent
//! connection carrying a warm [`ConnArena`]: after one warm-up pass has
//! grown the connection's read buffer, response staging, and request
//! scratch, three further rounds of 16 pipelined infer requests each must
//! allocate **nothing**.
//!
//! Scope, stated honestly: the coordinator *submit* itself (the
//! `Tensor` the request is copied into, and the per-request `mpsc`
//! response channel) allocates by design — exactly as it does for the
//! in-process `Client` API, whose compute-side budget
//! `tests/alloc_steady_state.rs` pins. This file pins the complement:
//! everything HTTP adds on top of that API — head parsing, JSON body
//! scanning, dispatch, response formatting — costs zero allocations per
//! request at steady state, the same `Scratch`-arena discipline the
//! compute hot path lives by.
//!
//! This file contains exactly one test so no concurrent test thread can
//! pollute the global allocation counter.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::{self, Read, Write};
use std::sync::atomic::{AtomicU64, Ordering};

use tpu_imac::serve_http::conn::{serve_connection, App, ConnArena, HttpLimits, ResponseBuf};
use tpu_imac::serve_http::router::write_infer_response;
use tpu_imac::serve_http::scanner::{scan_infer, InferRequest};

struct CountingAlloc;

static ALLOCS: AtomicU64 = AtomicU64::new(0);

// SAFETY: pure pass-through to `System` — every method forwards its exact
// arguments, so `System`'s layout/pointer contract is preserved verbatim;
// the only extra work is a Relaxed counter bump, which cannot allocate.
unsafe impl GlobalAlloc for CountingAlloc {
    // SAFETY: caller upholds `GlobalAlloc::alloc`'s layout contract; we
    // forward it unchanged to `System`.
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }
    // SAFETY: `ptr`/`layout` come from a matching `alloc` on `System`.
    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
    // SAFETY: caller upholds `realloc`'s contract; forwarded unchanged.
    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }
    // SAFETY: caller upholds `alloc_zeroed`'s contract; forwarded unchanged.
    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

/// Replayable in-memory persistent connection: each round rewinds the
/// same scripted request bytes and recycles the output buffer (capacity
/// kept), so steady-state rounds touch no heap of their own.
struct LoopStream {
    input: Vec<u8>,
    pos: usize,
    /// Bytes handed out per `read()` — small, so framing repeatedly
    /// crosses read boundaries like a real socket.
    chunk: usize,
    out: Vec<u8>,
}

impl LoopStream {
    fn rewind(&mut self) {
        self.pos = 0;
        self.out.clear();
    }
}

impl Read for LoopStream {
    fn read(&mut self, buf: &mut [u8]) -> io::Result<usize> {
        let n = self.chunk.min(buf.len()).min(self.input.len() - self.pos);
        buf[..n].copy_from_slice(&self.input[self.pos..self.pos + n]);
        self.pos += n;
        Ok(n)
    }
}

impl Write for LoopStream {
    fn write(&mut self, buf: &[u8]) -> io::Result<usize> {
        self.out.extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> io::Result<()> {
        Ok(())
    }
}

/// The wire path with the coordinator handoff stubbed at the submit
/// boundary: real body scan into reusable request scratch, real response
/// formatting from a fixed score vector. (The submit itself — `Tensor`
/// copy + `mpsc` channel — allocates per request by design in both the
/// HTTP and in-process APIs; see the module doc.)
struct WireApp {
    req: InferRequest,
    scores: Vec<f32>,
    served: u64,
    checksum: f32,
}

impl App for WireApp {
    fn handle(&mut self, method: &str, path: &str, body: &[u8], resp: &mut ResponseBuf) {
        assert_eq!((method, path), ("POST", "/v1/infer"));
        scan_infer(body, &mut self.req).expect("scripted request is valid");
        assert_eq!(self.req.image.len(), 784);
        assert_eq!(self.req.model, "lenet");
        // Consume the scanned image so the scan can't be optimized away.
        self.checksum += self.req.image.iter().sum::<f32>();
        self.served += 1;
        write_infer_response(resp, self.served, 7, 1234, &self.scores);
    }
}

#[test]
fn warmed_persistent_connection_infer_path_allocates_nothing() {
    // Build the scripted connection OUTSIDE the counted region: 16
    // pipelined infer requests with a 784-value image each.
    let mut image = String::with_capacity(784 * 7);
    image.push('[');
    for i in 0..784usize {
        if i > 0 {
            image.push(',');
        }
        image.push_str(&format!("{:.4}", ((i % 23) as f64 - 11.0) / 16.0));
    }
    image.push(']');
    let body = format!("{{\"model\":\"lenet\",\"image\":{image},\"timeout_ms\":50}}");
    let request = format!(
        "POST /v1/infer HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    let per_round = 16usize;
    let mut stream = LoopStream {
        input: request.repeat(per_round).into_bytes(),
        pos: 0,
        chunk: 1536,
        out: Vec::new(),
    };
    let mut arena = ConnArena::new();
    let mut app = WireApp {
        req: InferRequest::new(),
        scores: vec![0.01, -0.5, 1.25, 0.0, 3.5, -2.0, 0.125, 9.0, -0.25, 0.75],
        served: 0,
        checksum: 0.0,
    };
    let limits = HttpLimits::default();

    // Warm-up: one full round grows every reusable buffer to the
    // workload's high-water mark (read buffer, response head/body
    // staging, scanner string/image scratch, output capture).
    serve_connection(&mut stream, &mut arena, &mut app, &limits, &|| false).unwrap();
    assert_eq!(app.served as usize, per_round, "warm-up served every request");
    assert_eq!(
        stream.out.matches_200(),
        per_round,
        "warm-up: every request answered 200"
    );

    // Steady state: three more rounds on the same (warm) connection state
    // must perform exactly zero heap allocations.
    let before = ALLOCS.load(Ordering::SeqCst);
    for _ in 0..3 {
        stream.rewind();
        serve_connection(&mut stream, &mut arena, &mut app, &limits, &|| false).unwrap();
    }
    let delta = ALLOCS.load(Ordering::SeqCst) - before;
    assert_eq!(app.served as usize, per_round * 4, "steady state served every request");
    assert_eq!(stream.out.matches_200(), per_round, "steady state: every request answered 200");
    assert!(app.checksum.is_finite());
    assert_eq!(
        delta, 0,
        "warmed persistent-connection POST /v1/infer path performed {delta} heap \
         allocations across {} requests (want 0)",
        per_round * 3
    );
}

/// Count `HTTP/1.1 200` status lines without allocating a String.
trait Count200 {
    fn matches_200(&self) -> usize;
}

impl Count200 for Vec<u8> {
    fn matches_200(&self) -> usize {
        self.windows(14).filter(|w| *w == b"HTTP/1.1 200 O").count()
    }
}
