//! PJRT runtime integration: load the JAX-AOT HLO artifacts, execute them,
//! and cross-validate against the rust NN engine — the proof that the
//! three-layer stack composes (Pallas kernel → JAX graph → HLO text →
//! xla-crate PJRT → rust).
//!
//! These tests are artifact-gated: they skip (with a notice) when
//! `artifacts/` hasn't been built yet, so `cargo test` works pre-`make`.

use tpu_imac::deploy::DeploymentSpec;
use tpu_imac::imac::ImacConfig;
use tpu_imac::nn::Tensor;
use tpu_imac::runtime::Runtime;
use tpu_imac::util::rng::Xoshiro256;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("TPU_IMAC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/manifest.json")).exists()
        && std::path::Path::new(&format!("{dir}/weights_lenet.json")).exists()
    {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn rand_image(rng: &mut Xoshiro256) -> Tensor {
    Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect())
}

#[test]
fn conv_artifact_matches_rust_engine() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    rt.check_spec(&ImacConfig::default()).unwrap();
    let exe = rt.load("lenet_conv_b1.hlo.txt").unwrap();
    let model = DeploymentSpec::json_file("lenet", format!("{dir}/weights_lenet.json"))
        .build()
        .unwrap()
        .model;

    let mut rng = Xoshiro256::seed_from_u64(11);
    for _ in 0..4 {
        let img = rand_image(&mut rng);
        let pjrt_feats = exe.run_f32(&img.data).unwrap();
        let rust_feats = model.conv_features(&img);
        assert_eq!(pjrt_feats.len(), rust_feats.len());
        let mut max_diff = 0.0f32;
        for (a, b) in pjrt_feats.iter().zip(&rust_feats) {
            max_diff = max_diff.max((a - b).abs());
        }
        assert!(max_diff < 1e-3, "conv features diverge: max diff {max_diff}");
    }
}

#[test]
fn full_artifact_matches_composed_pipeline() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let conv = rt.load("lenet_conv_b1.hlo.txt").unwrap();
    let conv_name = conv.name.clone();
    rt.load("lenet_full_b1.hlo.txt").unwrap();
    rt.load("imac_fc_b1.hlo.txt").unwrap();

    let mut rng = Xoshiro256::seed_from_u64(13);
    for _ in 0..4 {
        let img = rand_image(&mut rng);
        let feats = rt.get(&conv_name).unwrap().run_f32(&img.data).unwrap();
        let signs: Vec<f32> =
            feats.iter().map(|&v| if v >= 0.0 { 1.0 } else { -1.0 }).collect();
        let fc_out = rt.get("imac_fc_b1.hlo.txt").unwrap().run_f32(&signs).unwrap();
        let full_out = rt.get("lenet_full_b1.hlo.txt").unwrap().run_f32(&img.data).unwrap();
        for (a, b) in fc_out.iter().zip(&full_out) {
            assert!((a - b).abs() < 1e-5, "composition mismatch: {a} vs {b}");
        }
    }
}

#[test]
fn pjrt_fc_matches_rust_imac_fabric() {
    // The Pallas imac kernel (lowered into HLO) and the rust analog fabric
    // must agree on the same ternary weights — the L1/L3 numerics contract.
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let fc = rt.load("imac_fc_b1.hlo.txt").unwrap();
    let model = DeploymentSpec::json_file("lenet", format!("{dir}/weights_lenet.json"))
        .build()
        .unwrap()
        .model;
    let n_in = model.fabric.n_in();
    let mut rng = Xoshiro256::seed_from_u64(17);
    for _ in 0..4 {
        let signs: Vec<f32> =
            (0..n_in).map(|_| if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 }).collect();
        let pjrt_out = fc.run_f32(&signs).unwrap();
        let rust_out = model.fabric.forward(&signs);
        assert_eq!(pjrt_out.len(), rust_out.len());
        for (a, b) in pjrt_out.iter().zip(&rust_out) {
            assert!((a - b).abs() < 1e-4, "L1-vs-L3 mismatch: {a} vs {b}");
        }
    }
}

#[test]
fn end_to_end_predictions_agree_native_vs_pjrt() {
    let Some(dir) = artifacts_dir() else { return };
    let mut rt = Runtime::open(&dir).unwrap();
    let full = rt.load("lenet_full_b1.hlo.txt").unwrap();
    let model = DeploymentSpec::json_file("lenet", format!("{dir}/weights_lenet.json"))
        .build()
        .unwrap()
        .model;
    let mut rng = Xoshiro256::seed_from_u64(19);
    let mut agree = 0;
    let n = 16;
    for _ in 0..n {
        let img = rand_image(&mut rng);
        let pjrt_scores = full.run_f32(&img.data).unwrap();
        let rust_pred = model.predict(&img);
        let pjrt_pred = tpu_imac::util::stats::argmax(&pjrt_scores);
        if rust_pred == pjrt_pred {
            agree += 1;
        }
    }
    // Bit-identical float paths are not guaranteed (XLA fuses differently),
    // but predictions must agree on essentially all random inputs.
    assert!(agree >= n - 1, "only {agree}/{n} predictions agree");
}
