//! Serving-stack integration: coordinator + backends end to end, including
//! the cross-precision conformance suite (fp32 vs dynamic-int8 vs
//! calibrated-int8 workers over the same batch).

use std::time::Duration;

use tpu_imac::coordinator::{Coordinator, CoordinatorConfig, NativeBackend, PjrtConvBackend};
use tpu_imac::imac::{AdcConfig, ImacConfig};
use tpu_imac::nn::synthetic::{lenet_weights_doc, mobilenet_mini_weights_doc};
use tpu_imac::nn::{DeployedModel, PrecisionPolicy, Scratch, Tensor};
use tpu_imac::quant::{calibrate_conv_ops, CalibrationTable};
use tpu_imac::runtime::Runtime;
use tpu_imac::util::rng::Xoshiro256;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("TPU_IMAC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/weights_lenet.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn load_model(dir: &str) -> DeployedModel {
    DeployedModel::load(
        &format!("{dir}/weights_lenet.json"),
        &ImacConfig::default(),
        AdcConfig { bits: 0, full_scale: 1.0 },
        0,
    )
    .unwrap()
}

#[test]
fn native_serving_matches_direct_inference() {
    let Some(dir) = artifacts_dir() else { return };
    let oracle = load_model(&dir);
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 4, ..Default::default() },
        move || Box::new(NativeBackend::new(load_model(&dir2))),
    );
    let client = coord.client();
    let mut rng = Xoshiro256::seed_from_u64(23);
    for _ in 0..12 {
        let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
        let want = oracle.predict(&img);
        let resp = client.infer_blocking(img).unwrap();
        assert_eq!(resp.predicted, want);
        assert!(resp.latency < Duration::from_secs(5));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, 12);
    coord.shutdown();
}

#[test]
fn pjrt_serving_matches_native_predictions() {
    let Some(dir) = artifacts_dir() else { return };
    if !std::path::Path::new(&format!("{dir}/lenet_conv_b8.hlo.txt")).exists() {
        eprintln!("SKIP: batch-8 conv artifact missing");
        return;
    }
    let oracle = load_model(&dir);
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 8, ..Default::default() },
        move || {
            let model = load_model(&dir2);
            let mut rt = Runtime::open(&dir2).unwrap();
            rt.check_spec(&ImacConfig::default()).unwrap();
            rt.load("lenet_conv_b8.hlo.txt").unwrap();
            Box::new(PjrtConvBackend::new(rt, "lenet_conv_b8.hlo.txt", model).unwrap())
        },
    );
    let client = coord.client();
    let mut rng = Xoshiro256::seed_from_u64(29);
    let mut pairs = Vec::new();
    for _ in 0..24 {
        let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
        let want = oracle.predict(&img);
        pairs.push((want, client.submit(img).unwrap().1));
    }
    let mut agree = 0;
    for (want, rx) in pairs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        if resp.predicted == want {
            agree += 1;
        }
    }
    assert!(agree >= 23, "only {agree}/24 predictions agree");
    coord.shutdown();
}

/// Cross-precision conformance: serve the same batch through fp32,
/// dynamic-int8 and calibrated-int8 native workers on a depthwise
/// (MobileNet-style) stack. Asserts per-deployment determinism, top-1
/// agreement across precisions, `metrics.int8_images` /
/// `metrics.calibrated_images` accounting, and that calibrated workers
/// never run the per-image max-abs scan (`metrics.maxabs_scans` = 0).
/// Self-contained: synthetic weights, no `make artifacts` needed.
#[test]
fn cross_precision_conformance_fp32_dynamic_calibrated() {
    let mut rng = Xoshiro256::seed_from_u64(71);
    let doc = mobilenet_mini_weights_doc(&mut rng);
    let build = |precision: PrecisionPolicy, calib: Option<&CalibrationTable>| {
        DeployedModel::from_json_calibrated(
            &doc,
            &ImacConfig::default(),
            AdcConfig { bits: 0, full_scale: 1.0 },
            0,
            precision,
            calib,
        )
        .unwrap()
    };
    // Calibrate on samples from the same distribution as the test batch.
    let oracle = build(PrecisionPolicy::Fp32, None);
    let samples: Vec<Tensor> = (0..16)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();
    let table = calibrate_conv_ops(&oracle.conv_ops, &samples, 100.0).unwrap();

    let n = 24usize;
    let images: Vec<Tensor> = (0..n)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();

    // Serve the identical batch through each deployment; two passes per
    // coordinator prove determinism under arbitrary batching.
    let mut predictions: Vec<Vec<usize>> = Vec::new();
    let variants: [(PrecisionPolicy, Option<CalibrationTable>); 3] = [
        (PrecisionPolicy::Fp32, None),
        (PrecisionPolicy::Int8, None),
        (PrecisionPolicy::Int8, Some(table.clone())),
    ];
    for (precision, calib) in variants {
        let is_calibrated = calib.is_some();
        let doc2 = doc.clone();
        let coord = Coordinator::start(
            CoordinatorConfig { max_batch: 5, ..Default::default() },
            move || {
                let m = DeployedModel::from_json_calibrated(
                    &doc2,
                    &ImacConfig::default(),
                    AdcConfig { bits: 0, full_scale: 1.0 },
                    0,
                    precision,
                    calib.as_ref(),
                )
                .unwrap();
                Box::new(NativeBackend::new(m))
            },
        );
        let client = coord.client();
        let mut passes: Vec<Vec<usize>> = Vec::new();
        for _ in 0..2 {
            let rxs: Vec<_> = images
                .iter()
                .map(|img| client.submit(img.clone()).unwrap().1)
                .collect();
            passes.push(
                rxs.into_iter()
                    .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap().predicted)
                    .collect(),
            );
        }
        assert_eq!(passes[0], passes[1], "{:?} serving must be deterministic", precision);

        let snap = coord.metrics.snapshot();
        assert_eq!(snap.completed, 2 * n as u64);
        assert_eq!(snap.gemm_images, 2 * n as u64);
        match precision {
            PrecisionPolicy::Fp32 => {
                assert_eq!(snap.int8_images, 0, "fp32 worker must not count int8 images");
                assert_eq!(snap.maxabs_scans, 0, "fp32 worker never scans ranges");
            }
            PrecisionPolicy::Int8 => {
                assert_eq!(snap.int8_images, 2 * n as u64, "int8 image accounting");
                if is_calibrated {
                    assert_eq!(snap.calibrated_images, 2 * n as u64);
                    assert_eq!(
                        snap.maxabs_scans, 0,
                        "calibrated worker must not run the max-abs pass"
                    );
                } else {
                    // 5 quantized layers (3 conv + 2 dwconv) per image.
                    assert_eq!(snap.calibrated_images, 0);
                    assert_eq!(snap.maxabs_scans, 2 * n as u64 * 5);
                }
            }
        }
        predictions.push(passes.into_iter().next().unwrap());
        coord.shutdown();
    }

    // Per-image top-1 must agree across precisions (random weights put
    // features near the sign threshold, so the floor is 80%, not 100% —
    // see the engine-level agreement tests for the rationale).
    let [p32, p8d, p8c] = [&predictions[0], &predictions[1], &predictions[2]];
    let agree = |a: &Vec<usize>, b: &Vec<usize>| a.iter().zip(b.iter()).filter(|(x, y)| x == y).count();
    assert!(
        agree(p32, p8d) * 100 >= n * 80,
        "fp32 vs dynamic-int8 agreement {}/{n}",
        agree(p32, p8d)
    );
    assert!(
        agree(p32, p8c) * 100 >= n * 80,
        "fp32 vs calibrated-int8 agreement {}/{n}",
        agree(p32, p8c)
    );
    assert!(
        agree(p8d, p8c) * 100 >= n * 80,
        "dynamic vs calibrated int8 agreement {}/{n}",
        agree(p8d, p8c)
    );
}

/// Batched-vs-per-row FC equivalence (the bit-sliced FC hot path's
/// acceptance test): on one conv feature block, the batch-at-a-time fabric
/// path — layer-1 popcount bitplanes + cache-blocked batched analog MVM +
/// ADC — must reproduce the per-row `forward_into` chain **bit-for-bit**;
/// and a coordinator serving the same images must account every one of
/// them to `metrics.imac_bitplane_images` (the deployment's fabric is
/// ideal). Self-contained: synthetic LeNet weights (256→120→84→10 FC head
/// — a multi-layer chain with a >64-row bit-sliced first layer).
#[test]
fn batched_fc_path_bit_exact_vs_per_row_and_counted() {
    let mut rng = Xoshiro256::seed_from_u64(83);
    let doc = lenet_weights_doc(&mut rng);
    let build = || {
        DeployedModel::from_json_with(
            &doc,
            &ImacConfig::default(),
            AdcConfig { bits: 0, full_scale: 1.0 },
            0,
            PrecisionPolicy::Fp32,
        )
        .unwrap()
    };
    let m = build();
    assert!(m.fabric.uses_bitplane_path());
    let n = 9usize; // not a multiple of the 4-image register block
    let images: Vec<Tensor> = (0..n)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();
    let refs: Vec<&Tensor> = images.iter().collect();

    // One conv pass for the whole batch, then compare the two FC paths on
    // the identical bridged feature block.
    let mut s = Scratch::new();
    let Scratch {
        cols,
        cols_i8,
        act_i8,
        acc_i32,
        act_a,
        act_b,
        fc_a,
        fc_b,
        fc_bits,
        grow_events,
        maxabs_scans,
    } = &mut s;
    let feats = m.plan.run_parts(
        &refs, cols, cols_i8, act_i8, acc_i32, act_a, act_b, grow_events, maxabs_scans,
    );
    DeployedModel::bridge_in_place(feats);
    let flen = m.plan.feat_len();
    let mut want = Vec::new();
    for row in feats.chunks_exact(flen) {
        want.extend_from_slice(m.fabric.forward_into(row, fc_a, fc_b));
    }
    let got = m.fabric.forward_batch_into(feats, n, fc_bits, fc_a, fc_b).to_vec();
    assert_eq!(got, want, "batched FC path must be bit-exact vs the per-row fabric path");

    // Serve the same images: predictions must match the per-image hot
    // path, and the bit-sliced layer-1 accounting must cover every image.
    let doc2 = doc.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 4, ..Default::default() },
        move || {
            let m = DeployedModel::from_json_with(
                &doc2,
                &ImacConfig::default(),
                AdcConfig { bits: 0, full_scale: 1.0 },
                0,
                PrecisionPolicy::Fp32,
            )
            .unwrap();
            Box::new(NativeBackend::new(m))
        },
    );
    let client = coord.client();
    let rxs: Vec<_> = images.iter().map(|img| client.submit(img.clone()).unwrap().1).collect();
    let served: Vec<usize> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap().predicted)
        .collect();
    let mut s2 = Scratch::new();
    for (img, &p) in images.iter().zip(&served) {
        let want_p = tpu_imac::util::stats::argmax(m.infer_into(img, &mut s2));
        assert_eq!(p, want_p, "served prediction diverges from the per-image hot path");
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, n as u64);
    assert_eq!(
        snap.imac_bitplane_images, n as u64,
        "every served image must be accounted to the bit-sliced layer-1 path"
    );
    assert_eq!(snap.gemm_images, n as u64);
    coord.shutdown();
}

#[test]
fn metrics_accumulate_under_load() {
    let Some(dir) = artifacts_dir() else { return };
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 8, ..Default::default() },
        move || Box::new(NativeBackend::new(load_model(&dir2))),
    );
    let client = coord.client();
    let mut rng = Xoshiro256::seed_from_u64(31);
    let rxs: Vec<_> = (0..40)
        .map(|_| {
            let img =
                Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
            client.submit(img).unwrap().1
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, 40);
    assert!(snap.batches >= 5);
    assert!(snap.p95_latency_us >= snap.p50_latency_us);
    assert!(snap.conv_us_total > 0 && snap.imac_us_total > 0);
    coord.shutdown();
}
