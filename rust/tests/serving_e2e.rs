//! Serving-stack integration: coordinator + backends end to end, including
//! the cross-precision conformance suite (fp32 vs dynamic-int8 vs
//! calibrated-int8 workers over the same batch) and the multi-model
//! registry (interleaved tagged requests, per-model metrics, hot swap,
//! clean unknown-model errors).

use std::sync::Arc;
use std::time::Duration;

use tpu_imac::coordinator::{
    Coordinator, CoordinatorConfig, FaultPlan, ModelRegistry, NativeBackend, PjrtConvBackend,
    SchedPolicy, ServeError,
};
use tpu_imac::deploy::DeploymentSpec;
use tpu_imac::nn::synthetic::{lenet_weights_doc, mobilenet_mini_weights_doc};
use tpu_imac::nn::{DeployedModel, PrecisionPolicy, Scratch, Tensor};
use tpu_imac::quant::{calibrate_conv_ops, CalibrationTable};
use tpu_imac::runtime::Runtime;
use tpu_imac::util::rng::Xoshiro256;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("TPU_IMAC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/weights_lenet.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn load_model(dir: &str) -> Arc<DeployedModel> {
    DeploymentSpec::json_file("lenet", format!("{dir}/weights_lenet.json"))
        .build()
        .unwrap()
        .model
}

#[test]
fn native_serving_matches_direct_inference() {
    let Some(dir) = artifacts_dir() else { return };
    let oracle = load_model(&dir);
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 4, ..Default::default() },
        move || Box::new(NativeBackend::new(load_model(&dir2))),
    );
    let client = coord.client();
    let mut rng = Xoshiro256::seed_from_u64(23);
    for _ in 0..12 {
        let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
        let want = oracle.predict(&img);
        let resp = client.infer_blocking(img).unwrap();
        assert_eq!(resp.predicted, want);
        assert!(resp.latency < Duration::from_secs(5));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, 12);
    coord.shutdown();
}

#[test]
fn pjrt_serving_matches_native_predictions() {
    let Some(dir) = artifacts_dir() else { return };
    if !std::path::Path::new(&format!("{dir}/lenet_conv_b8.hlo.txt")).exists() {
        eprintln!("SKIP: batch-8 conv artifact missing");
        return;
    }
    let oracle = load_model(&dir);
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 8, ..Default::default() },
        move || {
            let model = load_model(&dir2);
            let mut rt = Runtime::open(&dir2).unwrap();
            rt.check_spec(&tpu_imac::imac::ImacConfig::default()).unwrap();
            rt.load("lenet_conv_b8.hlo.txt").unwrap();
            Box::new(PjrtConvBackend::new(rt, "lenet_conv_b8.hlo.txt", model).unwrap())
        },
    );
    let client = coord.client();
    let mut rng = Xoshiro256::seed_from_u64(29);
    let mut pairs = Vec::new();
    for _ in 0..24 {
        let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
        let want = oracle.predict(&img);
        pairs.push((want, client.submit(img).unwrap().1));
    }
    let mut agree = 0;
    for (want, rx) in pairs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        if resp.predicted == want {
            agree += 1;
        }
    }
    assert!(agree >= 23, "only {agree}/24 predictions agree");
    coord.shutdown();
}

/// Cross-precision conformance: serve the same batch through fp32,
/// dynamic-int8 and calibrated-int8 native workers on a depthwise
/// (MobileNet-style) stack. Asserts per-deployment determinism, top-1
/// agreement across precisions, `metrics.int8_images` /
/// `metrics.calibrated_images` accounting, and that calibrated workers
/// never run the per-image max-abs scan (`metrics.maxabs_scans` = 0).
/// Self-contained: synthetic weights, no `make artifacts` needed.
#[test]
fn cross_precision_conformance_fp32_dynamic_calibrated() {
    let mut rng = Xoshiro256::seed_from_u64(71);
    let doc = mobilenet_mini_weights_doc(&mut rng);
    // Calibrate on samples from the same distribution as the test batch.
    let oracle = DeploymentSpec::doc("mm", doc.clone()).build().unwrap().model;
    let samples: Vec<Tensor> = (0..16)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();
    let table = calibrate_conv_ops(&oracle.conv_ops, &samples, 100.0).unwrap();

    let n = 24usize;
    let images: Vec<Tensor> = (0..n)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();

    // Serve the identical batch through each deployment; two passes per
    // coordinator prove determinism under arbitrary batching.
    let mut predictions: Vec<Vec<usize>> = Vec::new();
    let variants: [(PrecisionPolicy, Option<CalibrationTable>); 3] = [
        (PrecisionPolicy::Fp32, None),
        (PrecisionPolicy::Int8, None),
        (PrecisionPolicy::Int8, Some(table.clone())),
    ];
    for (precision, calib) in variants {
        let is_calibrated = calib.is_some();
        let mut spec = DeploymentSpec::doc("mm", doc.clone()).precision(precision);
        if let Some(t) = calib {
            spec = spec.calibration_table(t);
        }
        let registry = ModelRegistry::with_specs(&[spec]).unwrap();
        let coord = Coordinator::start_registry(
            CoordinatorConfig { max_batch: 5, ..Default::default() },
            registry,
        )
        .unwrap();
        let client = coord.client();
        let mut passes: Vec<Vec<usize>> = Vec::new();
        for _ in 0..2 {
            let rxs: Vec<_> = images
                .iter()
                .map(|img| client.submit(img.clone()).unwrap().1)
                .collect();
            passes.push(
                rxs.into_iter()
                    .map(|rx| {
                        rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap().predicted
                    })
                    .collect(),
            );
        }
        assert_eq!(passes[0], passes[1], "{:?} serving must be deterministic", precision);

        let snap = coord.metrics.snapshot();
        assert_eq!(snap.completed, 2 * n as u64);
        assert_eq!(snap.gemm_images, 2 * n as u64);
        match precision {
            PrecisionPolicy::Fp32 => {
                assert_eq!(snap.int8_images, 0, "fp32 worker must not count int8 images");
                assert_eq!(snap.maxabs_scans, 0, "fp32 worker never scans ranges");
            }
            PrecisionPolicy::Int8 => {
                assert_eq!(snap.int8_images, 2 * n as u64, "int8 image accounting");
                if is_calibrated {
                    assert_eq!(snap.calibrated_images, 2 * n as u64);
                    assert_eq!(
                        snap.maxabs_scans, 0,
                        "calibrated worker must not run the max-abs pass"
                    );
                } else {
                    // 5 quantized layers (3 conv + 2 dwconv) per image.
                    assert_eq!(snap.calibrated_images, 0);
                    assert_eq!(snap.maxabs_scans, 2 * n as u64 * 5);
                }
            }
        }
        predictions.push(passes.into_iter().next().unwrap());
        coord.shutdown();
    }

    // Per-image top-1 must agree across precisions (random weights put
    // features near the sign threshold, so the floor is 80%, not 100% —
    // see the engine-level agreement tests for the rationale).
    let [p32, p8d, p8c] = [&predictions[0], &predictions[1], &predictions[2]];
    let agree =
        |a: &Vec<usize>, b: &Vec<usize>| a.iter().zip(b.iter()).filter(|(x, y)| x == y).count();
    assert!(
        agree(p32, p8d) * 100 >= n * 80,
        "fp32 vs dynamic-int8 agreement {}/{n}",
        agree(p32, p8d)
    );
    assert!(
        agree(p32, p8c) * 100 >= n * 80,
        "fp32 vs calibrated-int8 agreement {}/{n}",
        agree(p32, p8c)
    );
    assert!(
        agree(p8d, p8c) * 100 >= n * 80,
        "dynamic vs calibrated int8 agreement {}/{n}",
        agree(p8d, p8c)
    );
}

/// The multi-model registry acceptance test: two named deployments with
/// different precision policies served concurrently from one queue.
/// Interleaved tagged requests route to the right plan (predictions match
/// each deployment's own hot path), per-model metrics account each stream
/// separately, an unknown model id is a clean error (not a panic), plain
/// `submit` keeps routing to the default deployment, and
/// `ModelRegistry::swap` hot-reloads one deployment without disturbing
/// the other or dropping responses.
#[test]
fn multi_model_registry_routes_accounts_and_swaps() {
    let mut rng = Xoshiro256::seed_from_u64(97);
    let lenet_doc = lenet_weights_doc(&mut rng);
    let mm_doc = mobilenet_mini_weights_doc(&mut rng);
    let registry = Arc::new(ModelRegistry::new());
    registry
        .register(&DeploymentSpec::doc("lenet", lenet_doc.clone()))
        .unwrap();
    registry
        .register(
            &DeploymentSpec::doc("mm", mm_doc.clone()).precision(PrecisionPolicy::Int8),
        )
        .unwrap();

    // Reference models built the same way the registry builds them.
    let lenet_oracle = registry.deployment("lenet").unwrap();
    let mm_oracle = registry.deployment("mm").unwrap();
    assert_eq!(lenet_oracle.precision(), PrecisionPolicy::Fp32);
    assert_eq!(mm_oracle.precision(), PrecisionPolicy::Int8);

    let coord = Coordinator::start_registry(
        CoordinatorConfig { max_batch: 4, workers: 2, ..Default::default() },
        registry.clone(),
    )
    .unwrap();
    let client = coord.client();

    // Unknown model id: clean client-side error, nothing enqueued.
    let img = Tensor::from_vec(28, 28, 1, vec![0.1; 784]);
    let err = client.submit_to("resnet50", img).unwrap_err();
    let msg = format!("{err:#}");
    assert!(msg.contains("unknown model 'resnet50'"), "{msg}");
    assert!(msg.contains("lenet") && msg.contains("mm"), "{msg}");

    // Interleaved tagged traffic across both deployments.
    let n = 30usize;
    let mut rxs = Vec::with_capacity(n);
    let mut images = Vec::with_capacity(n);
    for i in 0..n {
        let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect());
        let name = if i % 2 == 0 { "lenet" } else { "mm" };
        rxs.push(client.submit_to(name, img.clone()).unwrap().1);
        images.push(img);
    }
    let (mut s_lenet, mut s_mm) = (Scratch::new(), Scratch::new());
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap();
        let want = if i % 2 == 0 {
            tpu_imac::util::stats::argmax(lenet_oracle.model.infer_into(&images[i], &mut s_lenet))
        } else {
            tpu_imac::util::stats::argmax(mm_oracle.model.infer_into(&images[i], &mut s_mm))
        };
        assert_eq!(resp.predicted, want, "request {i} routed to the wrong deployment?");
    }

    // Plain submit routes to the default deployment (slot 0 = lenet).
    let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect());
    let resp = client.infer_blocking(img.clone()).unwrap();
    assert_eq!(
        resp.predicted,
        tpu_imac::util::stats::argmax(lenet_oracle.model.infer_into(&img, &mut s_lenet))
    );

    // Per-model metrics: each stream accounted under its own name, and the
    // global counters cover both; int8 images only from the mm stream.
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, n as u64 + 1);
    let by_name = |name: &str| {
        snap.models
            .iter()
            .find(|m| m.name == name)
            .unwrap_or_else(|| panic!("per-model metrics missing '{name}'"))
    };
    assert_eq!(by_name("lenet").completed, n as u64 / 2 + 1);
    assert_eq!(by_name("mm").completed, n as u64 / 2);
    assert!(by_name("lenet").p95_latency_us >= by_name("lenet").p50_latency_us);
    assert_eq!(snap.int8_images, n as u64 / 2, "only the mm stream is int8");

    // Hot swap: replace 'mm' with an fp32 deployment of the same weights.
    // Requests submitted after the swap must run the new plan; 'lenet'
    // is untouched.
    registry
        .swap("mm", &DeploymentSpec::doc("mm", mm_doc.clone()))
        .unwrap();
    let mm_v2 = registry.deployment("mm").unwrap();
    assert_eq!(mm_v2.precision(), PrecisionPolicy::Fp32);
    let mut s_v2 = Scratch::new();
    for _ in 0..8 {
        let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect());
        let resp = client.infer_blocking_to("mm", img.clone()).unwrap();
        let want = tpu_imac::util::stats::argmax(mm_v2.model.infer_into(&img, &mut s_v2));
        assert_eq!(resp.predicted, want, "post-swap request must run the swapped plan");
    }
    let snap2 = coord.metrics.snapshot();
    let mm2 = snap2.models.iter().find(|m| m.name == "mm").unwrap();
    assert_eq!(mm2.completed, n as u64 / 2 + 8, "post-swap batches keep accounting to 'mm'");
    assert_eq!(snap2.completed, n as u64 + 9, "no response was dropped across the swap");
    coord.shutdown();
}

/// Batched-vs-per-row FC equivalence (the bit-sliced FC hot path's
/// acceptance test): on one conv feature block, the batch-at-a-time fabric
/// path — layer-1 popcount bitplanes + cache-blocked batched analog MVM +
/// ADC — must reproduce the per-row `forward_into` chain **bit-for-bit**;
/// and a coordinator serving the same images must account every one of
/// them to `metrics.imac_bitplane_images` (the deployment's fabric is
/// ideal). Self-contained: synthetic LeNet weights (256→120→84→10 FC head
/// — a multi-layer chain with a >64-row bit-sliced first layer).
#[test]
fn batched_fc_path_bit_exact_vs_per_row_and_counted() {
    let mut rng = Xoshiro256::seed_from_u64(83);
    let doc = lenet_weights_doc(&mut rng);
    let m = DeploymentSpec::doc("lenet", doc.clone()).build().unwrap().model;
    assert!(m.fabric.uses_bitplane_path());
    let n = 9usize; // not a multiple of the 4-image register block
    let images: Vec<Tensor> = (0..n)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();
    let refs: Vec<&Tensor> = images.iter().collect();

    // One conv pass for the whole batch, then compare the two FC paths on
    // the identical bridged feature block.
    let mut s = Scratch::new();
    let feats = m.plan.run(&refs, &mut s.conv);
    DeployedModel::bridge_in_place(feats);
    let flen = m.plan.feat_len();
    let fc = &mut s.fc;
    let mut want = Vec::new();
    for row in feats.chunks_exact(flen) {
        want.extend_from_slice(m.fabric.forward_into(row, &mut fc.a, &mut fc.b));
    }
    let got = m.fabric.forward_batch_into(feats, n, &mut fc.bits, &mut fc.a, &mut fc.b).to_vec();
    assert_eq!(got, want, "batched FC path must be bit-exact vs the per-row fabric path");

    // Serve the same images: predictions must match the per-image hot
    // path, and the bit-sliced layer-1 accounting must cover every image.
    let registry = ModelRegistry::with_specs(&[DeploymentSpec::doc("lenet", doc)]).unwrap();
    let coord = Coordinator::start_registry(
        CoordinatorConfig { max_batch: 4, ..Default::default() },
        registry,
    )
    .unwrap();
    let client = coord.client();
    let rxs: Vec<_> = images.iter().map(|img| client.submit(img.clone()).unwrap().1).collect();
    let served: Vec<usize> = rxs
        .into_iter()
        .map(|rx| rx.recv_timeout(Duration::from_secs(60)).unwrap().unwrap().predicted)
        .collect();
    let mut s2 = Scratch::new();
    for (img, &p) in images.iter().zip(&served) {
        let want_p = tpu_imac::util::stats::argmax(m.infer_into(img, &mut s2));
        assert_eq!(p, want_p, "served prediction diverges from the per-image hot path");
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, n as u64);
    assert_eq!(
        snap.imac_bitplane_images, n as u64,
        "every served image must be accounted to the bit-sliced layer-1 path"
    );
    assert_eq!(snap.gemm_images, n as u64);
    coord.shutdown();
}

/// Non-ideal deployments now run the cache-blocked **batched analog**
/// kernel instead of the per-row fallback, and the split is observable:
/// a 7-image batch on a noisy fabric must account 4 images to
/// `imac_analog_batch_images` (one full micro-kernel block) and 3 to
/// `imac_analog_tail_images` (the per-row remainder) — never to the
/// bitplane counter — while the backend's scores stay bit-identical to
/// the per-image hot path. The snapshot also surfaces the active SIMD
/// level and the autotuned tile label.
#[test]
fn nonideal_backend_runs_batched_analog_path_and_counts() {
    use tpu_imac::coordinator::InferenceBackend;
    use tpu_imac::imac::{CrossbarConfig, ImacConfig};
    let mut rng = Xoshiro256::seed_from_u64(97);
    let doc = lenet_weights_doc(&mut rng);
    let imac = ImacConfig {
        crossbar: CrossbarConfig { wire_alpha: 0.02, amp_offset_sigma: 0.05, ..Default::default() },
        ..Default::default()
    };
    let m = DeploymentSpec::doc("noisy", doc).imac(imac).fabric_seed(7).build().unwrap().model;
    assert!(!m.fabric.uses_bitplane_path(), "a noisy fabric must not claim the bitplane path");
    assert_eq!(m.fabric.fast_path(), "analog-batch");

    let images: Vec<Tensor> = (0..7)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();
    let refs: Vec<&Tensor> = images.iter().collect();
    let mut backend = NativeBackend::new(m.clone());
    let metrics = tpu_imac::metrics::Metrics::new();
    let scores = backend.infer_batch(&refs, &metrics);
    let mut s = Scratch::new();
    for (img, got) in images.iter().zip(&scores) {
        assert_eq!(
            got.as_slice(),
            m.infer_into(img, &mut s),
            "backend scores diverge from the per-image hot path"
        );
    }
    let snap = metrics.snapshot();
    assert_eq!(snap.imac_bitplane_images, 0, "noisy fabric must not count as bit-sliced");
    assert_eq!(snap.imac_analog_batch_images, 4, "one full 4-image block");
    assert_eq!(snap.imac_analog_tail_images, 3, "per-row remainder");
    assert!(["scalar", "avx2", "neon"].contains(&snap.simd_level));
    assert!(snap.tile.contains("imac kc="), "{}", snap.tile);
}

/// The resilience-layer anchor: a chaos soak with deterministic fault
/// injection across two models — in-batch panics, one worker death, NaN
/// output corruption and slow batches — while a second thread hot-swaps
/// one deployment (including one injected build failure that must roll
/// back). The contract under all of it: **every accepted request gets
/// exactly one reply** — a response or a typed [`ServeError`] — with zero
/// hangs and zero lost replies, the supervisor restarts the dead worker,
/// and swap generations stay monotonic. Self-contained synthetic weights;
/// fixed seeds end to end.
#[test]
fn chaos_soak_zero_lost_responses() {
    let mut rng = Xoshiro256::seed_from_u64(0xC4A05);
    let lenet_doc = lenet_weights_doc(&mut rng);
    let mm_doc = mobilenet_mini_weights_doc(&mut rng);
    let lenet_faults = FaultPlan {
        seed: 1,
        panic_every: Some(7),
        slow_every: Some(5),
        slow_us: 300,
        nan_every: Some(9),
        ..Default::default()
    };
    let mm_faults =
        FaultPlan { seed: 2, die_on_batch: Some(3), nan_every: Some(6), ..Default::default() };
    let registry = Arc::new(ModelRegistry::new());
    registry
        .register(&DeploymentSpec::doc("lenet", lenet_doc.clone()).faults(lenet_faults))
        .unwrap();
    registry
        .register(
            &DeploymentSpec::doc("mm", mm_doc)
                .precision(PrecisionPolicy::Int8)
                .faults(mm_faults),
        )
        .unwrap();

    let coord = Coordinator::start_registry(
        CoordinatorConfig { max_batch: 4, workers: 3, ..Default::default() },
        registry.clone(),
    )
    .unwrap();
    let client = coord.client();

    // Concurrent hot swaps while the soak runs: clean swaps bump the
    // generation monotonically; the injected build failure must leave the
    // live generation serving (rollback).
    let swapper = {
        let registry = registry.clone();
        let lenet_doc = lenet_doc.clone();
        std::thread::spawn(move || {
            let mut last_gen = registry.resolve(0).unwrap().0;
            for i in 0..4 {
                std::thread::sleep(Duration::from_millis(15));
                if i == 2 {
                    let bad = DeploymentSpec::doc("lenet", lenet_doc.clone())
                        .faults(FaultPlan { fail_build: true, ..Default::default() });
                    let err = registry.swap("lenet", &bad).unwrap_err();
                    assert!(format!("{err:#}").contains("injected build failure"), "{err:#}");
                    assert_eq!(
                        registry.resolve(0).unwrap().0,
                        last_gen,
                        "failed swap must not bump the generation"
                    );
                    continue;
                }
                registry
                    .swap("lenet", &DeploymentSpec::doc("lenet", lenet_doc.clone()))
                    .unwrap();
                let generation = registry.resolve(0).unwrap().0;
                assert!(generation > last_gen, "swap generations must be monotonic");
                last_gen = generation;
            }
        })
    };

    // 240 requests round-robin across both models; a few carry (generous)
    // deadline budgets so the guarded submit path soaks too.
    let n = 240usize;
    let mut rxs = Vec::with_capacity(n);
    for i in 0..n {
        let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect());
        let name = if i % 2 == 0 { "lenet" } else { "mm" };
        let rx = if i % 16 == 3 {
            client.submit_to_within(name, img, Duration::from_secs(30)).unwrap().1
        } else {
            client.submit_to(name, img).unwrap().1
        };
        rxs.push(rx);
    }

    let (mut ok, mut typed) = (0u64, 0u64);
    for (i, rx) in rxs.into_iter().enumerate() {
        let reply = rx
            .recv_timeout(Duration::from_secs(60))
            .unwrap_or_else(|_| panic!("request {i}: no reply within 60s — a request was lost"));
        match reply {
            Ok(_) => ok += 1,
            Err(
                ServeError::WorkerFault { .. }
                | ServeError::NumericFault { .. }
                | ServeError::DeadlineExceeded { .. },
            ) => typed += 1,
            Err(other) => panic!("request {i}: unexpected serve error under chaos: {other}"),
        }
        // Exactly one reply per request: the sender is consumed by it.
        assert!(rx.try_recv().is_err(), "request {i}: second reply on one channel");
    }
    assert_eq!(ok + typed, n as u64, "every request answered exactly once");
    swapper.join().unwrap();

    // The injected worker death must be observed and repaired by the
    // supervisor (its poll runs every few ms; give it a bounded moment).
    let t0 = std::time::Instant::now();
    while coord.metrics.snapshot().worker_restarts < 1 {
        assert!(
            t0.elapsed() < Duration::from_secs(10),
            "supervisor never restarted the dead worker"
        );
        std::thread::sleep(Duration::from_millis(5));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, ok, "completed counts exactly the Ok replies");
    assert!(snap.worker_panics >= 1, "panic_every plan never fired");
    assert!(snap.numeric_faults >= 1, "nan_every plan never fired");
    assert!(snap.slow_batches >= 1, "slow_every plan never fired");
    assert!(
        snap.faulted + snap.deadline_drops >= typed,
        "fault accounting covers the typed error replies"
    );
    coord.shutdown();
}

/// Deadline expiry and admission control, end to end: a single slow
/// deployment with an explicit `queue_quota` — an over-quota submit is
/// shed with a typed `ShedLoad` at submit time, a queued request whose
/// budget lapses is answered `DeadlineExceeded` without being computed,
/// and both show up in the global and per-model metrics.
#[test]
fn chaos_deadline_expiry_and_load_shed_are_typed() {
    let mut rng = Xoshiro256::seed_from_u64(0xD1CE);
    let doc = lenet_weights_doc(&mut rng);
    // Every batch sleeps ~40ms before executing, so the queue observably
    // backs up behind the worker.
    let faults =
        FaultPlan { seed: 4, slow_every: Some(1), slow_us: 40_000, ..Default::default() };
    let registry = ModelRegistry::with_specs(&[DeploymentSpec::doc("a", doc)
        .queue_quota(3)
        .faults(faults)])
    .unwrap();
    let coord = Coordinator::start_registry(
        CoordinatorConfig { max_batch: 1, workers: 1, ..Default::default() },
        registry,
    )
    .unwrap();
    let client = coord.client();
    let img = || Tensor::from_vec(28, 28, 1, vec![0.25; 784]);

    // r1 is drained immediately; the worker then sleeps inside the
    // injected slow path, pinning r2..r4 in the queue.
    let r1 = client.submit_to("a", img()).unwrap().1;
    std::thread::sleep(Duration::from_millis(20));
    let r2 = client.submit_to("a", img()).unwrap().1;
    let r3 = client.submit_to_within("a", img(), Duration::from_millis(1)).unwrap().1;
    let r4 = client.submit_to("a", img()).unwrap().1;
    // Queue depth for 'a' is now 3 == quota: the next submit is shed.
    let err = client.submit_to("a", img()).unwrap_err();
    match err.downcast_ref::<ServeError>() {
        Some(ServeError::ShedLoad { model, queued, quota }) => {
            assert_eq!((model.as_str(), *queued, *quota), ("a", 3, 3));
        }
        other => panic!("expected ShedLoad, got {other:?} ({err:#})"),
    }

    // Live requests complete; the expired one is answered, not computed.
    assert!(r1.recv_timeout(Duration::from_secs(60)).unwrap().is_ok());
    assert!(r2.recv_timeout(Duration::from_secs(60)).unwrap().is_ok());
    match r3.recv_timeout(Duration::from_secs(60)).unwrap() {
        Err(ServeError::DeadlineExceeded { waited_us }) => {
            assert!(waited_us >= 1_000, "budget was 1ms, waited only {waited_us}us");
        }
        other => panic!("expected DeadlineExceeded, got {other:?}"),
    }
    assert!(r4.recv_timeout(Duration::from_secs(60)).unwrap().is_ok());

    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, 3);
    assert_eq!(snap.shed, 1);
    assert_eq!(snap.deadline_drops, 1);
    let m = snap.models.iter().find(|m| m.name == "a").expect("per-model metrics for 'a'");
    assert_eq!((m.shed, m.deadline_drops), (1, 1));
    coord.shutdown();
}

/// The SLO-scheduling regression anchor: a flooding tenant keeps its own
/// queue pinned at quota while a cold tenant submits sporadic blocking
/// requests. Under [`SchedPolicy::Weighted`] the cold tenant's p95 queue
/// wait must stay bounded (it re-enters at the current virtual time and
/// wins the next batch slot); under the old head-of-queue FIFO drain the
/// same workload demonstrably starves it — every cold request waits for
/// the flooder's entire backlog. Deterministic fault injection (a fixed
/// per-batch slow sleep) keeps the queue observably backed up on any
/// machine; the assertion is relative (FIFO ≥ 2× weighted) plus a generous
/// absolute bound, so it is robust to debug-vs-release compute speed.
#[test]
fn weighted_scheduling_bounds_cold_tenant_queue_wait() {
    use std::sync::atomic::{AtomicBool, Ordering};

    /// Runs the flood-vs-cold workload under `policy` and returns the cold
    /// deployment's p95 queue wait in microseconds.
    fn cold_p95_queue_wait_us(policy: SchedPolicy) -> f64 {
        let mut rng = Xoshiro256::seed_from_u64(0x5C0);
        let doc = lenet_weights_doc(&mut rng);
        // Every batch sleeps ~5ms inside the worker, so service is slow
        // relative to submission no matter how fast the machine is.
        let slow = |seed| FaultPlan {
            seed,
            slow_every: Some(1),
            slow_us: 5_000,
            ..Default::default()
        };
        let registry = ModelRegistry::with_specs(&[
            DeploymentSpec::doc("flood", doc.clone()).queue_quota(48).faults(slow(3)),
            DeploymentSpec::doc("cold", doc).queue_quota(8).faults(slow(4)),
        ])
        .unwrap();
        let coord = Coordinator::start_registry(
            CoordinatorConfig {
                max_batch: 8,
                workers: 1,
                batch_timeout: Duration::ZERO,
                scheduling: policy,
                ..Default::default()
            },
            registry,
        )
        .unwrap();

        // Flooding tenant: fire-and-forget submits (receivers dropped — the
        // exactly-one-reply contract tolerates unclaimed replies), retrying
        // whenever admission control sheds it at quota.
        let stop = Arc::new(AtomicBool::new(false));
        let flooder = {
            let client = coord.client();
            let stop = stop.clone();
            std::thread::spawn(move || {
                let img = Tensor::from_vec(28, 28, 1, vec![0.2; 784]);
                while !stop.load(Ordering::Relaxed) {
                    if client.submit_to("flood", img.clone()).is_err() {
                        std::thread::sleep(Duration::from_micros(200));
                    }
                }
            })
        };

        // Let the flood back up to quota, then run sporadic blocking cold
        // traffic — one request at a time, like a latency-sensitive tenant.
        std::thread::sleep(Duration::from_millis(50));
        let client = coord.client();
        for _ in 0..20 {
            let img = Tensor::from_vec(28, 28, 1, vec![0.4; 784]);
            client.infer_blocking_to("cold", img).unwrap();
        }

        let snap = coord.metrics.snapshot();
        let cold = snap.models.iter().find(|m| m.name == "cold").unwrap();
        assert_eq!(cold.completed, 20, "every cold request must complete");
        let p95 = cold.p95_queue_wait_us;
        stop.store(true, Ordering::Relaxed);
        flooder.join().unwrap();
        coord.shutdown();
        p95
    }

    let weighted = cold_p95_queue_wait_us(SchedPolicy::Weighted);
    let fifo = cold_p95_queue_wait_us(SchedPolicy::FifoHead);
    // The FIFO baseline must actually starve: 6 flood batches of injected
    // 5ms sleeps alone put the cold wait past 20ms.
    assert!(
        fifo > 20_000.0,
        "FIFO baseline never backed up (cold p95 queue wait {fifo:.0}us) — \
         the flooder is not saturating the queue"
    );
    assert!(
        fifo >= 2.0 * weighted,
        "weighted scheduling must beat head-of-queue FIFO by 2x on cold-tenant \
         p95 queue wait; got weighted {weighted:.0}us vs fifo {fifo:.0}us"
    );
    assert!(
        weighted < 1_500_000.0,
        "cold tenant p95 queue wait unbounded under weighted scheduling: {weighted:.0}us"
    );
}

#[test]
fn metrics_accumulate_under_load() {
    let Some(dir) = artifacts_dir() else { return };
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 8, ..Default::default() },
        move || Box::new(NativeBackend::new(load_model(&dir2))),
    );
    let client = coord.client();
    let mut rng = Xoshiro256::seed_from_u64(31);
    let rxs: Vec<_> = (0..40)
        .map(|_| {
            let img =
                Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
            client.submit(img).unwrap().1
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap().unwrap();
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, 40);
    assert!(snap.batches >= 5);
    assert!(snap.p95_latency_us >= snap.p50_latency_us);
    assert!(snap.conv_us_total > 0 && snap.imac_us_total > 0);
    coord.shutdown();
}
