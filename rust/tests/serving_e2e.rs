//! Serving-stack integration: coordinator + backends end to end.

use std::time::Duration;

use tpu_imac::coordinator::{Coordinator, CoordinatorConfig, NativeBackend, PjrtConvBackend};
use tpu_imac::imac::{AdcConfig, ImacConfig};
use tpu_imac::nn::{DeployedModel, Tensor};
use tpu_imac::runtime::Runtime;
use tpu_imac::util::rng::Xoshiro256;

fn artifacts_dir() -> Option<String> {
    let dir = std::env::var("TPU_IMAC_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if std::path::Path::new(&format!("{dir}/weights_lenet.json")).exists() {
        Some(dir)
    } else {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        None
    }
}

fn load_model(dir: &str) -> DeployedModel {
    DeployedModel::load(
        &format!("{dir}/weights_lenet.json"),
        &ImacConfig::default(),
        AdcConfig { bits: 0, full_scale: 1.0 },
        0,
    )
    .unwrap()
}

#[test]
fn native_serving_matches_direct_inference() {
    let Some(dir) = artifacts_dir() else { return };
    let oracle = load_model(&dir);
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 4, ..Default::default() },
        move || Box::new(NativeBackend::new(load_model(&dir2))),
    );
    let client = coord.client();
    let mut rng = Xoshiro256::seed_from_u64(23);
    for _ in 0..12 {
        let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
        let want = oracle.predict(&img);
        let resp = client.infer_blocking(img).unwrap();
        assert_eq!(resp.predicted, want);
        assert!(resp.latency < Duration::from_secs(5));
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, 12);
    coord.shutdown();
}

#[test]
fn pjrt_serving_matches_native_predictions() {
    let Some(dir) = artifacts_dir() else { return };
    if !std::path::Path::new(&format!("{dir}/lenet_conv_b8.hlo.txt")).exists() {
        eprintln!("SKIP: batch-8 conv artifact missing");
        return;
    }
    let oracle = load_model(&dir);
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 8, ..Default::default() },
        move || {
            let model = load_model(&dir2);
            let mut rt = Runtime::open(&dir2).unwrap();
            rt.check_spec(&ImacConfig::default()).unwrap();
            rt.load("lenet_conv_b8.hlo.txt").unwrap();
            Box::new(PjrtConvBackend::new(rt, "lenet_conv_b8.hlo.txt", model).unwrap())
        },
    );
    let client = coord.client();
    let mut rng = Xoshiro256::seed_from_u64(29);
    let mut pairs = Vec::new();
    for _ in 0..24 {
        let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
        let want = oracle.predict(&img);
        pairs.push((want, client.submit(img).unwrap().1));
    }
    let mut agree = 0;
    for (want, rx) in pairs {
        let resp = rx.recv_timeout(Duration::from_secs(60)).unwrap();
        if resp.predicted == want {
            agree += 1;
        }
    }
    assert!(agree >= 23, "only {agree}/24 predictions agree");
    coord.shutdown();
}

#[test]
fn metrics_accumulate_under_load() {
    let Some(dir) = artifacts_dir() else { return };
    let dir2 = dir.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch: 8, ..Default::default() },
        move || Box::new(NativeBackend::new(load_model(&dir2))),
    );
    let client = coord.client();
    let mut rng = Xoshiro256::seed_from_u64(31);
    let rxs: Vec<_> = (0..40)
        .map(|_| {
            let img =
                Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
            client.submit(img).unwrap().1
        })
        .collect();
    for rx in rxs {
        rx.recv().unwrap();
    }
    let snap = coord.metrics.snapshot();
    assert_eq!(snap.completed, 40);
    assert!(snap.batches >= 5);
    assert!(snap.p95_latency_us >= snap.p50_latency_us);
    assert!(snap.conv_us_total > 0 && snap.imac_us_total > 0);
    coord.shutdown();
}
