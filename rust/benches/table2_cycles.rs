//! Bench + regeneration of Table 2's cycle columns: per-model TPU vs
//! TPU-IMAC cycles, printed in paper row order with the published values,
//! plus the wall-time cost of the cycle simulation itself.

use tpu_imac::arch;
use tpu_imac::report::paper_rows;
use tpu_imac::systolic::{ArrayConfig, SramConfig};
use tpu_imac::util::bench::{black_box, BenchSuite};
use tpu_imac::util::table::{Align, Table};
use tpu_imac::workload::zoo;

fn main() {
    // --- Regenerate the table rows ---
    let cfg = ArrayConfig::default();
    let sram = SramConfig::default();
    let evals = arch::evaluate_suite(&cfg, &sram).expect("suite");
    let paper: Vec<_> = paper_rows();
    let mut t = Table::new(&["model", "TPU kcyc", "(paper)", "TPU-IMAC kcyc", "(paper)"])
        .with_title("Table 2 — cycles (regenerated)")
        .with_aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for (e, (key, p)) in evals.iter().zip(&paper) {
        t.row(vec![
            key.to_string(),
            format!("{:.3}", e.cycles_tpu as f64 / 1e3),
            format!("{:.3}", p.kcycles_tpu),
            format!("{:.3}", e.cycles_hybrid as f64 / 1e3),
            format!("{:.3}", p.kcycles_hybrid),
        ]);
    }
    println!("{}", t.to_ascii());

    // --- Bench the simulator itself ---
    let mut suite = BenchSuite::new("table2_cycles simulation cost");
    let models = zoo::paper_suite();
    let total_layers: usize = models.iter().map(|m| m.layers.len()).sum();
    suite.bench_throughput("evaluate_suite(7 CNNs)", total_layers as f64, move || {
        let evals = arch::evaluate_suite(&cfg, &sram).unwrap();
        black_box(evals.iter().map(|e| e.cycles_tpu).sum::<u64>())
    });
    suite.run_cli();
}
