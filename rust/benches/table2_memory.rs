//! Bench + regeneration of Table 2's memory columns (exact reproduction:
//! decimal MB, conv FP32 SRAM + ternary 2-bit RRAM).

use tpu_imac::arch::MemoryFootprint;
use tpu_imac::report::paper_rows;
use tpu_imac::util::bench::{black_box, BenchSuite};
use tpu_imac::util::table::{Align, Table};
use tpu_imac::workload::zoo;

fn main() {
    let models = zoo::paper_suite();
    let paper = paper_rows();
    let mut t = Table::new(&[
        "model", "TPU MB", "(paper)", "SRAM MB", "(paper)", "RRAM MB", "(paper)",
    ])
    .with_title("Table 2 — memory (regenerated)")
    .with_aligns(&[
        Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Right,
        Align::Right,
    ]);
    for (m, (key, p)) in models.iter().zip(&paper) {
        let f = MemoryFootprint::of(m);
        t.row(vec![
            key.to_string(),
            format!("{:.3}", f.tpu_mb()),
            format!("{:.3}", p.mem_tpu_mb),
            format!("{:.3}", f.sram_mb()),
            format!("{:.3}", p.mem_sram_mb),
            format!("{:.3}", f.rram_mb()),
            format!("{:.3}", p.mem_rram_mb),
        ]);
    }
    println!("{}", t.to_ascii());

    let mut suite = BenchSuite::new("table2_memory model cost");
    suite.bench("footprint(7 CNNs)", move || {
        let s: u64 = zoo::paper_suite().iter().map(|m| MemoryFootprint::of(m).tpu_bytes).sum();
        black_box(s)
    });
    suite.run_cli();
}
