//! Hot-path bench: the systolic simulators. The analytic model must stay
//! cheap enough to sweep (tables regenerate in <1 s); the register-level
//! stepper is the validation path (PE-slot updates/s).

use tpu_imac::systolic::{analytic, array, ArrayConfig};
use tpu_imac::util::bench::{black_box, BenchSuite};
use tpu_imac::workload::{zoo, GemmShape};

fn main() {
    let mut suite = BenchSuite::new("systolic simulators");

    // Analytic model over the whole MobileNetV1 (30 GEMM layers incl. all
    // depthwise groups).
    suite.bench("analytic mobilenetv1 (all layers)", || {
        let m = zoo::mobilenet_v1(tpu_imac::workload::Dataset::Cifar10);
        let cfg = ArrayConfig::default();
        let mut acc = 0u64;
        for l in &m.layers {
            if let Some(g) = l.gemm() {
                acc = acc.wrapping_add(analytic::simulate_gemm(&cfg, &g).cycles);
            }
        }
        black_box(acc)
    });

    // Single analytic GEMM (the inner primitive).
    suite.bench_throughput("analytic single GEMM", 1.0, || {
        let g = GemmShape::new(1024, 576, 128);
        black_box(analytic::simulate_gemm(&ArrayConfig::default(), &g).cycles)
    });

    // Register-level stepper: 32x32 fold with K=64 = 65,536 MACs and
    // ~32*32*(32+32+64) PE-slot updates.
    let a: Vec<Vec<f32>> =
        (0..32).map(|i| (0..64).map(|k| ((i * k) % 7) as f32).collect()).collect();
    let b: Vec<Vec<f32>> =
        (0..64).map(|k| (0..32).map(|j| ((k + j) % 5) as f32).collect()).collect();
    let pe_slots = (32 * 32 * (32 + 32 + 64)) as f64;
    suite.bench_throughput("stepper 32x32 fold K=64 (PE-slots)", pe_slots, move || {
        let run = array::run_os_fold(&a, &b);
        black_box(run.total_macs)
    });

    let results = suite.run_cli();
    for r in &results {
        if r.name.contains("stepper") {
            if let Some(tput) = r.throughput_per_sec() {
                println!("stepper: {:.1} M PE-slot updates/s", tput / 1e6);
            }
        }
    }
}
