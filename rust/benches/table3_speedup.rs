//! Bench + regeneration of Table 3: speedup and memory reduction per model,
//! against the paper's published factors.

use tpu_imac::arch;
use tpu_imac::report::paper_rows;
use tpu_imac::systolic::{ArrayConfig, SramConfig};
use tpu_imac::util::bench::{black_box, BenchSuite};
use tpu_imac::util::table::{Align, Table};

fn main() {
    let cfg = ArrayConfig::default();
    let sram = SramConfig::default();
    let evals = arch::evaluate_suite(&cfg, &sram).expect("suite");
    let paper = paper_rows();
    let mut t = Table::new(&["model", "speedup", "(paper)", "mem reduction", "(paper)"])
        .with_title("Table 3 — speedup & memory reduction (regenerated)")
        .with_aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    let mut max_rel_err: f64 = 0.0;
    for (e, (key, p)) in evals.iter().zip(&paper) {
        let rel = (e.speedup() - p.speedup).abs() / p.speedup;
        max_rel_err = max_rel_err.max(rel);
        t.row(vec![
            key.to_string(),
            format!("{:.2}x", e.speedup()),
            format!("{:.2}x", p.speedup),
            format!("{:.2}%", e.memory_reduction() * 100.0),
            format!("{:.2}%", p.mem_reduction_pct),
        ]);
    }
    println!("{}", t.to_ascii());
    println!("max speedup relative error vs paper: {:.1}%", max_rel_err * 100.0);

    let mut suite = BenchSuite::new("table3 evaluation cost");
    suite.bench("evaluate_suite+derive", move || {
        let evals = arch::evaluate_suite(&cfg, &sram).unwrap();
        black_box(evals.iter().map(|e| (e.speedup() * 1000.0) as u64).sum::<u64>())
    });
    suite.run_cli();
}
