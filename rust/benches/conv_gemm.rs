//! Conv hot-path bench: the scalar direct oracle (`nn::ops`, the seed's
//! request path) vs the batched im2col+GEMM engine (`nn::gemm` +
//! `ConvPlan`) — in both conv precisions — on the LeNet conv stack at
//! batch 8, the serving shape; plus a MobileNet-style depthwise stack in
//! fp32 / dynamic-int8 / calibrated-int8 (the DwI8 kernel and the
//! static-activation-scale path).
//!
//! Run with `cargo bench --bench conv_gemm`; add `-- --json
//! BENCH_hotpath.json` for a machine-readable report tracked across PRs
//! (CI uploads it as a workflow artifact). Existing row names keep their
//! PR-1/PR-2 spelling so the JSON series stay comparable; the dw rows and
//! the FC rows (per-row fp32 fabric vs the bit-sliced batched FC hot
//! path) are new series. The int8 rows track the fp32→int8 speedup
//! (acceptance floor 1.30×: both staged matrices drop to 1/4 the memory
//! traffic); the FC rows track the bit-sliced speedup (popcount layer 1 +
//! 4-image-blocked analog MVM — see EXPERIMENTS.md §Bit-sliced FC).

use std::sync::Arc;

use tpu_imac::deploy::DeploymentSpec;
use tpu_imac::nn::synthetic::{lenet_weights_doc, mobilenet_mini_weights_doc};
use tpu_imac::nn::{DeployedModel, PrecisionPolicy, Scratch, Tensor};
use tpu_imac::quant::{calibrate_conv_ops, CalibrationTable};
use tpu_imac::util::bench::{black_box, BenchSuite};
use tpu_imac::util::json::Json;
use tpu_imac::util::rng::Xoshiro256;

const BATCH: usize = 8;

fn load_model(doc: &Json, precision: PrecisionPolicy) -> Arc<DeployedModel> {
    load_model_calibrated(doc, precision, None)
}

fn load_model_calibrated(
    doc: &Json,
    precision: PrecisionPolicy,
    calib: Option<&CalibrationTable>,
) -> Arc<DeployedModel> {
    let mut spec = DeploymentSpec::doc("bench", doc.clone()).precision(precision);
    if let Some(t) = calib {
        spec = spec.calibration_table(t.clone());
    }
    spec.build().expect("synthetic model").model
}

/// Run the conv plan over the batch through a scratch arena (the hot path).
fn run_plan(m: &DeployedModel, imgs: &[Tensor], s: &mut Scratch) -> u64 {
    let refs: Vec<&Tensor> = imgs.iter().collect();
    let feats = m.plan.run(&refs, &mut s.conv);
    feats[0].to_bits() as u64
}

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(17);
    let doc = lenet_weights_doc(&mut rng);
    let images: Vec<Tensor> = (0..BATCH)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();

    // Sanity: fp32 paths must agree, and the int8 deployment must track
    // the fp32 one (top-1 agreement reported below) before we time them.
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let mut s = Scratch::new();
        for img in &images {
            let want = m.conv_features(img);
            let got = m.conv_features_into(img, &mut s);
            let d = tpu_imac::util::stats::max_abs_diff(got, &want);
            assert!(d < 1e-4, "paths diverge before benching: {d}");
        }
    }
    {
        let m32 = load_model(&doc, PrecisionPolicy::Fp32);
        let m8 = load_model(&doc, PrecisionPolicy::Int8);
        let (mut s32, mut s8) = (Scratch::new(), Scratch::new());
        let mut agree = 0;
        for img in &images {
            let p32 = tpu_imac::util::stats::argmax(m32.infer_into(img, &mut s32));
            let p8 = tpu_imac::util::stats::argmax(m8.infer_into(img, &mut s8));
            agree += (p32 == p8) as usize;
        }
        println!("int8 vs fp32 top-1 agreement on bench images: {agree}/{BATCH}");
    }

    let mut suite =
        BenchSuite::new("LeNet conv stack, batch 8: direct oracle vs im2col+GEMM (fp32 + int8)");
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let imgs = images.clone();
        suite.bench_throughput("direct conv (seed request path)", BATCH as f64, move || {
            let mut acc = 0u64;
            for img in &imgs {
                acc = acc.wrapping_add(m.conv_features(img)[0].to_bits() as u64);
            }
            acc
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let imgs = images.clone();
        let mut s = Scratch::new();
        // Row names predating the int8 split keep their PR-1 spelling so
        // the BENCH_hotpath.json series stays comparable across PRs.
        suite.bench_throughput("im2col+GEMM, per image", BATCH as f64, move || {
            let mut acc = 0u64;
            for img in &imgs {
                acc = acc.wrapping_add(m.conv_features_into(img, &mut s)[0].to_bits() as u64);
            }
            acc
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("im2col+GEMM, batched (hot path)", BATCH as f64, move || {
            black_box(run_plan(&m, &imgs, &mut s))
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Int8);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("im2col+GEMM int8, batched (hot path)", BATCH as f64, move || {
            black_box(run_plan(&m, &imgs, &mut s))
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("e2e conv+bridge+IMAC, batched", BATCH as f64, move || {
            let refs: Vec<&Tensor> = imgs.iter().collect();
            let mut acc = 0u64;
            m.infer_batch_into(&refs, &mut s, |_, scores| {
                acc = acc.wrapping_add(scores[0].to_bits() as u64);
            });
            acc
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Int8);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("e2e conv+bridge+IMAC int8, batched", BATCH as f64, move || {
            let refs: Vec<&Tensor> = imgs.iter().collect();
            let mut acc = 0u64;
            m.infer_batch_into(&refs, &mut s, |_, scores| {
                acc = acc.wrapping_add(scores[0].to_bits() as u64);
            });
            acc
        });
    }

    // Depthwise (MobileNet-mini) stack: fp32, dynamic int8 (the DwI8
    // kernel) and calibrated int8 (static scales, no max-abs pass). New
    // JSON series — existing row names above are untouched.
    let dw_doc = mobilenet_mini_weights_doc(&mut rng);
    let dw_oracle = load_model(&dw_doc, PrecisionPolicy::Fp32);
    let dw_table = calibrate_conv_ops(&dw_oracle.conv_ops, &images, 100.0).expect("calibrate");
    drop(dw_oracle);
    {
        let m = load_model(&dw_doc, PrecisionPolicy::Fp32);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("dw-stack fp32, batched (hot path)", BATCH as f64, move || {
            black_box(run_plan(&m, &imgs, &mut s))
        });
    }
    {
        let m = load_model(&dw_doc, PrecisionPolicy::Int8);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("dw-stack int8, batched (hot path)", BATCH as f64, move || {
            black_box(run_plan(&m, &imgs, &mut s))
        });
    }
    {
        let m = load_model_calibrated(&dw_doc, PrecisionPolicy::Int8, Some(&dw_table));
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput(
            "dw-stack int8 calibrated, batched (hot path)",
            BATCH as f64,
            move || black_box(run_plan(&m, &imgs, &mut s)),
        );
    }

    // FC section (LeNet 256→120→84→10 ternary head): per-row fp32 fabric
    // chain vs the bit-sliced batched hot path (layer-1 popcount bitplanes
    // + 4-image-blocked analog MVM). New JSON series — conv row names
    // above keep their frozen spelling. Inputs are the batch's real
    // bridged conv features, computed once outside the timed region.
    let fc_model = load_model(&doc, PrecisionPolicy::Fp32);
    let bridged: Vec<f32> = {
        let mut s = Scratch::new();
        let mut block = Vec::new();
        for img in &images {
            block.extend_from_slice(fc_model.conv_features_into(img, &mut s));
        }
        DeployedModel::bridge_in_place(&mut block);
        block
    };
    // Sanity: the two FC paths must agree bit-for-bit before we time them.
    {
        let mut s = Scratch::new();
        let flen = fc_model.fabric.n_in();
        let mut want = Vec::new();
        for row in bridged.chunks_exact(flen) {
            want.extend_from_slice(fc_model.fabric.forward_into(row, &mut s.fc.a, &mut s.fc.b));
        }
        let got = fc_model
            .fabric
            .forward_batch_into(&bridged, BATCH, &mut s.fc.bits, &mut s.fc.a, &mut s.fc.b)
            .to_vec();
        assert_eq!(got, want, "FC paths diverge before benching");
        assert!(fc_model.fabric.uses_bitplane_path());
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let block = bridged.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("FC fabric per-row fp32 (batch 8)", BATCH as f64, move || {
            let flen = m.fabric.n_in();
            let mut acc = 0u64;
            for row in block.chunks_exact(flen) {
                acc = acc.wrapping_add(
                    m.fabric.forward_into(row, &mut s.fc.a, &mut s.fc.b)[0].to_bits() as u64,
                );
            }
            acc
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let block = bridged.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("FC fabric bit-sliced batched (batch 8)", BATCH as f64, move || {
            let fc = &mut s.fc;
            let out =
                m.fabric.forward_batch_into(&block, BATCH, &mut fc.bits, &mut fc.a, &mut fc.b);
            black_box(out[0].to_bits() as u64)
        });
    }

    // SIMD kernel micro-rows (new, non-frozen series): the two dispatched
    // inner loops — i8×i8→i32 GEMM and the popcount bitplane MVM — timed
    // scalar-forced vs at the host's detected level. On a host without
    // AVX2/NEON (or under TPU_IMAC_SIMD=scalar) the pairs coincide and the
    // printed speedup is ~1.00x by construction.
    let host_level = tpu_imac::nn::simd::active();
    let (gm, gk, gn) = (128usize, 512usize, 256usize);
    let ga: Vec<i8> = (0..gm * gk).map(|i| ((i * 7 + 3) % 255) as i8).collect();
    let gb: Vec<i8> = (0..gk * gn).map(|i| ((i * 13 + 5) % 255) as i8).collect();
    let gscale_w = vec![0.02f32; gn];
    let gbias = vec![0.1f32; gn];
    for (row, level) in [
        ("i8 GEMM kernel scalar (128x512x256)", tpu_imac::nn::SimdLevel::Scalar),
        ("i8 GEMM kernel simd (128x512x256)", host_level),
    ] {
        let (a, b, sw, bias) = (ga.clone(), gb.clone(), gscale_w.clone(), gbias.clone());
        let mut acc = vec![0i32; gm * gn];
        let mut out = vec![0.0f32; gm * gn];
        suite.bench_throughput(row, (gm * gk * gn) as f64, move || {
            tpu_imac::nn::gemm::gemm_i8_requant_tiled_at(
                level, &a, gm, gk, &b, gn, 0.05, &sw, &bias, false, &mut acc, &mut out, 256, 4,
            );
            out[0].to_bits() as u64
        });
    }
    let (pn_in, pn_out) = (1024usize, 256usize);
    let pw: Vec<i8> = (0..pn_in * pn_out).map(|i| ((i % 3) as i8) - 1).collect();
    let mut prng = Xoshiro256::seed_from_u64(29);
    let xb = tpu_imac::imac::Crossbar::program(
        &pw,
        pn_in,
        pn_out,
        tpu_imac::imac::CrossbarConfig::default(),
        &mut prng,
    );
    let levels: Vec<f32> =
        (0..pn_in).map(|i| if i % 3 == 0 { -1.0 } else { 1.0 }).collect();
    let mut xbits = vec![0u64; tpu_imac::quant::bitplane_words(pn_in)];
    tpu_imac::quant::pack_sign_bitmask(&levels, &mut xbits);
    for (row, level) in [
        ("popcount bitplane MVM scalar (1024x256)", tpu_imac::nn::SimdLevel::Scalar),
        ("popcount bitplane MVM simd (1024x256)", host_level),
    ] {
        let (xb, xbits) = (xb.clone(), xbits.clone());
        let mut out = vec![0.0f32; pn_out];
        suite.bench_throughput(row, (pn_in * pn_out) as f64, move || {
            out.fill(0.0);
            xb.mvm_level_bits_acc_at(level, &xbits, 1, &mut out);
            out[0].to_bits() as u64
        });
    }

    let results = suite.run_cli();
    // Look rows up by name (not position) so inserting a bench row can
    // never silently corrupt the reported cross-PR speedup series.
    let mean = |name: &str| {
        results
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("bench row '{name}' missing"))
            .mean_ns
    };
    let direct = mean("direct conv (seed request path)");
    let gemm_f32 = mean("im2col+GEMM, batched (hot path)");
    let gemm_i8 = mean("im2col+GEMM int8, batched (hot path)");
    println!(
        "speedup (direct / batched fp32 GEMM): {:.2}x  [acceptance floor: 3.00x]",
        direct / gemm_f32
    );
    println!(
        "speedup (fp32 GEMM / int8 GEMM):      {:.2}x  [acceptance floor: 1.30x]",
        gemm_f32 / gemm_i8
    );
    let dw_f32 = mean("dw-stack fp32, batched (hot path)");
    let dw_i8_cal = mean("dw-stack int8 calibrated, batched (hot path)");
    println!(
        "speedup (dw-stack fp32 / int8 calibrated): {:.2}x",
        dw_f32 / dw_i8_cal
    );
    let fc_row = mean("FC fabric per-row fp32 (batch 8)");
    let fc_bits = mean("FC fabric bit-sliced batched (batch 8)");
    println!(
        "speedup (FC per-row fp32 / bit-sliced batched): {:.2}x  (EXPERIMENTS.md §Bit-sliced FC)",
        fc_row / fc_bits
    );
    let g_sc = mean("i8 GEMM kernel scalar (128x512x256)");
    let g_sd = mean("i8 GEMM kernel simd (128x512x256)");
    let p_sc = mean("popcount bitplane MVM scalar (1024x256)");
    let p_sd = mean("popcount bitplane MVM simd (1024x256)");
    println!(
        "speedup (scalar / '{}' kernels): i8 GEMM {:.2}x, popcount bitplane MVM {:.2}x",
        host_level.label(),
        g_sc / g_sd,
        p_sc / p_sd
    );

    // Steady-state allocation check across every deployment shape: after
    // warmup, a fresh scratch must converge and then never regrow — and a
    // calibrated int8 plan must never run the per-image max-abs pass.
    let configs: [(&Json, PrecisionPolicy, Option<&CalibrationTable>, &str); 5] = [
        (&doc, PrecisionPolicy::Fp32, None, "lenet fp32"),
        (&doc, PrecisionPolicy::Int8, None, "lenet int8"),
        (&dw_doc, PrecisionPolicy::Fp32, None, "dw-stack fp32"),
        (&dw_doc, PrecisionPolicy::Int8, None, "dw-stack int8"),
        (&dw_doc, PrecisionPolicy::Int8, Some(&dw_table), "dw-stack int8 calibrated"),
    ];
    for (model_doc, precision, calib, label) in configs {
        let m = load_model_calibrated(model_doc, precision, calib);
        let mut s = Scratch::new();
        let refs: Vec<&Tensor> = images.iter().collect();
        m.infer_batch_into(&refs, &mut s, |_, _| {});
        m.infer_batch_into(&refs, &mut s, |_, _| {});
        let warm = s.grow_events();
        for _ in 0..100 {
            m.infer_batch_into(&refs, &mut s, |_, _| {});
        }
        assert_eq!(s.grow_events(), warm, "{label} scratch arena regrew at steady state");
        if calib.is_some() {
            assert_eq!(
                s.maxabs_scans(), 0,
                "{label}: calibrated plan must perform zero max-abs scans"
            );
        }
        println!(
            "scratch arena [{label}]: {} KiB, {} grow events (all during warmup), zero steady-state growth, {} max-abs scans",
            s.bytes() / 1024,
            warm,
            s.maxabs_scans()
        );
    }

    // The PR-7 FC kernels share the same steady-state guarantee: the
    // batched analog micro-kernel (non-ideal fabric) and the multi-plane
    // popcount path (2-bit bridge) must not allocate once warm.
    use tpu_imac::imac::{CrossbarConfig, DeviceConfig, ImacConfig};
    let noisy = ImacConfig {
        crossbar: CrossbarConfig {
            device: DeviceConfig { sigma: 0.05, ..Default::default() },
            wire_alpha: 0.02,
            amp_offset_sigma: 0.01,
        },
        ..Default::default()
    };
    let multibit = ImacConfig { bridge_bits: 2, bridge_full_scale: 2.0, ..Default::default() };
    for (imac, label) in [(noisy, "lenet fp32 non-ideal"), (multibit, "lenet fp32 2-bit bridge")] {
        let m = DeploymentSpec::doc("bench", doc.clone())
            .imac(imac)
            .fabric_seed(7)
            .build()
            .expect("synthetic model")
            .model;
        let mut s = Scratch::new();
        let refs: Vec<&Tensor> = images.iter().collect();
        m.infer_batch_into(&refs, &mut s, |_, _| {});
        m.infer_batch_into(&refs, &mut s, |_, _| {});
        let warm = s.grow_events();
        for _ in 0..100 {
            m.infer_batch_into(&refs, &mut s, |_, _| {});
        }
        assert_eq!(s.grow_events(), warm, "{label} scratch arena regrew at steady state");
        println!(
            "scratch arena [{label}]: {} KiB, {} grow events (all during warmup), zero steady-state growth",
            s.bytes() / 1024,
            warm
        );
    }
}
