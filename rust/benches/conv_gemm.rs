//! Conv hot-path bench: the scalar direct oracle (`nn::ops`, the seed's
//! request path) vs the batched im2col+GEMM engine (`nn::gemm` +
//! `ConvPlan`) on the LeNet conv stack at batch 8 — the serving shape.
//!
//! Run with `cargo bench --bench conv_gemm`; add `-- --json
//! BENCH_hotpath.json` for a machine-readable report tracked across PRs.

use tpu_imac::imac::{AdcConfig, ImacConfig};
use tpu_imac::nn::synthetic::lenet_weights_doc;
use tpu_imac::nn::{DeployedModel, Scratch, Tensor};
use tpu_imac::util::bench::{black_box, BenchSuite};
use tpu_imac::util::json::Json;
use tpu_imac::util::rng::Xoshiro256;

const BATCH: usize = 8;

fn load_model(doc: &Json) -> DeployedModel {
    DeployedModel::from_json(
        doc,
        &ImacConfig::default(),
        AdcConfig { bits: 0, full_scale: 1.0 },
        0,
    )
    .expect("synthetic model")
}

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(17);
    let doc = lenet_weights_doc(&mut rng);
    let images: Vec<Tensor> = (0..BATCH)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();

    // Sanity: the two paths must agree before we time them.
    {
        let m = load_model(&doc);
        let mut s = Scratch::new();
        for img in &images {
            let want = m.conv_features(img);
            let got = m.conv_features_into(img, &mut s);
            let d = tpu_imac::util::stats::max_abs_diff(got, &want);
            assert!(d < 1e-4, "paths diverge before benching: {d}");
        }
    }

    let mut suite = BenchSuite::new("LeNet conv stack, batch 8: direct oracle vs im2col+GEMM");
    {
        let m = load_model(&doc);
        let imgs = images.clone();
        suite.bench_throughput("direct conv (seed request path)", BATCH as f64, move || {
            let mut acc = 0u64;
            for img in &imgs {
                acc = acc.wrapping_add(m.conv_features(img)[0].to_bits() as u64);
            }
            acc
        });
    }
    {
        let m = load_model(&doc);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("im2col+GEMM, per image", BATCH as f64, move || {
            let mut acc = 0u64;
            for img in &imgs {
                acc = acc.wrapping_add(m.conv_features_into(img, &mut s)[0].to_bits() as u64);
            }
            acc
        });
    }
    {
        let m = load_model(&doc);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("im2col+GEMM, batched (hot path)", BATCH as f64, move || {
            let refs: Vec<&Tensor> = imgs.iter().collect();
            let feats = m.plan.run_parts(
                &refs,
                &mut s.cols,
                &mut s.act_a,
                &mut s.act_b,
                &mut s.grow_events,
            );
            black_box(feats[0].to_bits() as u64)
        });
    }
    {
        let m = load_model(&doc);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("e2e conv+bridge+IMAC, batched", BATCH as f64, move || {
            let refs: Vec<&Tensor> = imgs.iter().collect();
            let mut acc = 0u64;
            m.infer_batch_into(&refs, &mut s, |_, scores| {
                acc = acc.wrapping_add(scores[0].to_bits() as u64);
            });
            acc
        });
    }

    let results = suite.run_cli();
    let direct = results[0].mean_ns;
    let gemm_batched = results[2].mean_ns;
    println!(
        "speedup (direct / batched GEMM): {:.2}x  [acceptance floor: 3.00x]",
        direct / gemm_batched
    );

    // Steady-state allocation check: after warmup (the bench loops above),
    // a fresh scratch must converge and then never regrow.
    let m = load_model(&doc);
    let mut s = Scratch::new();
    let refs: Vec<&Tensor> = images.iter().collect();
    m.infer_batch_into(&refs, &mut s, |_, _| {});
    m.infer_batch_into(&refs, &mut s, |_, _| {});
    let warm = s.grow_events;
    for _ in 0..100 {
        m.infer_batch_into(&refs, &mut s, |_, _| {});
    }
    assert_eq!(s.grow_events, warm, "scratch arena regrew at steady state");
    println!(
        "scratch arena: {} KiB, {} grow events (all during warmup), zero steady-state growth",
        s.bytes() / 1024,
        warm
    );
}
