//! Conv hot-path bench: the scalar direct oracle (`nn::ops`, the seed's
//! request path) vs the batched im2col+GEMM engine (`nn::gemm` +
//! `ConvPlan`) — in both conv precisions — on the LeNet conv stack at
//! batch 8, the serving shape.
//!
//! Run with `cargo bench --bench conv_gemm`; add `-- --json
//! BENCH_hotpath.json` for a machine-readable report tracked across PRs.
//! The int8 rows track the fp32→int8 speedup (acceptance floor 1.30×:
//! both staged matrices drop to 1/4 the memory traffic).

use tpu_imac::imac::{AdcConfig, ImacConfig};
use tpu_imac::nn::synthetic::lenet_weights_doc;
use tpu_imac::nn::{DeployedModel, PrecisionPolicy, Scratch, Tensor};
use tpu_imac::util::bench::{black_box, BenchSuite};
use tpu_imac::util::json::Json;
use tpu_imac::util::rng::Xoshiro256;

const BATCH: usize = 8;

fn load_model(doc: &Json, precision: PrecisionPolicy) -> DeployedModel {
    DeployedModel::from_json_with(
        doc,
        &ImacConfig::default(),
        AdcConfig { bits: 0, full_scale: 1.0 },
        0,
        precision,
    )
    .expect("synthetic model")
}

/// Run the conv plan over the batch through a scratch arena (the hot path).
fn run_plan(m: &DeployedModel, imgs: &[Tensor], s: &mut Scratch) -> u64 {
    let refs: Vec<&Tensor> = imgs.iter().collect();
    let feats = m.plan.run_parts(
        &refs,
        &mut s.cols,
        &mut s.cols_i8,
        &mut s.act_i8,
        &mut s.acc_i32,
        &mut s.act_a,
        &mut s.act_b,
        &mut s.grow_events,
    );
    feats[0].to_bits() as u64
}

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(17);
    let doc = lenet_weights_doc(&mut rng);
    let images: Vec<Tensor> = (0..BATCH)
        .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32() - 0.5).collect()))
        .collect();

    // Sanity: fp32 paths must agree, and the int8 deployment must track
    // the fp32 one (top-1 agreement reported below) before we time them.
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let mut s = Scratch::new();
        for img in &images {
            let want = m.conv_features(img);
            let got = m.conv_features_into(img, &mut s);
            let d = tpu_imac::util::stats::max_abs_diff(got, &want);
            assert!(d < 1e-4, "paths diverge before benching: {d}");
        }
    }
    {
        let m32 = load_model(&doc, PrecisionPolicy::Fp32);
        let m8 = load_model(&doc, PrecisionPolicy::Int8);
        let (mut s32, mut s8) = (Scratch::new(), Scratch::new());
        let mut agree = 0;
        for img in &images {
            let p32 = tpu_imac::util::stats::argmax(m32.infer_into(img, &mut s32));
            let p8 = tpu_imac::util::stats::argmax(m8.infer_into(img, &mut s8));
            agree += (p32 == p8) as usize;
        }
        println!("int8 vs fp32 top-1 agreement on bench images: {agree}/{BATCH}");
    }

    let mut suite =
        BenchSuite::new("LeNet conv stack, batch 8: direct oracle vs im2col+GEMM (fp32 + int8)");
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let imgs = images.clone();
        suite.bench_throughput("direct conv (seed request path)", BATCH as f64, move || {
            let mut acc = 0u64;
            for img in &imgs {
                acc = acc.wrapping_add(m.conv_features(img)[0].to_bits() as u64);
            }
            acc
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let imgs = images.clone();
        let mut s = Scratch::new();
        // Row names predating the int8 split keep their PR-1 spelling so
        // the BENCH_hotpath.json series stays comparable across PRs.
        suite.bench_throughput("im2col+GEMM, per image", BATCH as f64, move || {
            let mut acc = 0u64;
            for img in &imgs {
                acc = acc.wrapping_add(m.conv_features_into(img, &mut s)[0].to_bits() as u64);
            }
            acc
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("im2col+GEMM, batched (hot path)", BATCH as f64, move || {
            black_box(run_plan(&m, &imgs, &mut s))
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Int8);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("im2col+GEMM int8, batched (hot path)", BATCH as f64, move || {
            black_box(run_plan(&m, &imgs, &mut s))
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Fp32);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("e2e conv+bridge+IMAC, batched", BATCH as f64, move || {
            let refs: Vec<&Tensor> = imgs.iter().collect();
            let mut acc = 0u64;
            m.infer_batch_into(&refs, &mut s, |_, scores| {
                acc = acc.wrapping_add(scores[0].to_bits() as u64);
            });
            acc
        });
    }
    {
        let m = load_model(&doc, PrecisionPolicy::Int8);
        let imgs = images.clone();
        let mut s = Scratch::new();
        suite.bench_throughput("e2e conv+bridge+IMAC int8, batched", BATCH as f64, move || {
            let refs: Vec<&Tensor> = imgs.iter().collect();
            let mut acc = 0u64;
            m.infer_batch_into(&refs, &mut s, |_, scores| {
                acc = acc.wrapping_add(scores[0].to_bits() as u64);
            });
            acc
        });
    }

    let results = suite.run_cli();
    let direct = results[0].mean_ns;
    let gemm_f32 = results[2].mean_ns;
    let gemm_i8 = results[3].mean_ns;
    println!(
        "speedup (direct / batched fp32 GEMM): {:.2}x  [acceptance floor: 3.00x]",
        direct / gemm_f32
    );
    println!(
        "speedup (fp32 GEMM / int8 GEMM):      {:.2}x  [acceptance floor: 1.30x]",
        gemm_f32 / gemm_i8
    );

    // Steady-state allocation check for BOTH precisions: after warmup, a
    // fresh scratch must converge and then never regrow.
    for precision in [PrecisionPolicy::Fp32, PrecisionPolicy::Int8] {
        let m = load_model(&doc, precision);
        let mut s = Scratch::new();
        let refs: Vec<&Tensor> = images.iter().collect();
        m.infer_batch_into(&refs, &mut s, |_, _| {});
        m.infer_batch_into(&refs, &mut s, |_, _| {});
        let warm = s.grow_events;
        for _ in 0..100 {
            m.infer_batch_into(&refs, &mut s, |_, _| {});
        }
        assert_eq!(
            s.grow_events,
            warm,
            "{} scratch arena regrew at steady state",
            precision.label()
        );
        println!(
            "scratch arena [{}]: {} KiB, {} grow events (all during warmup), zero steady-state growth",
            precision.label(),
            s.bytes() / 1024,
            warm
        );
    }
}
