//! Hot-path bench: the IMAC analog fabric forward pass — the request-path
//! work the coordinator does per inference after the conv features arrive.
//! Reports MAC throughput for the paper's CIFAR head (1024->1024->10) and
//! the LeNet head, ideal and noisy.

use tpu_imac::imac::{AdcConfig, CrossbarConfig, DeviceConfig, ImacConfig, ImacFabric};
use tpu_imac::util::bench::{black_box, BenchSuite};
use tpu_imac::util::rng::Xoshiro256;

fn rand_tern(rng: &mut Xoshiro256, n: usize) -> Vec<i8> {
    (0..n).map(|_| (rng.next_below(3) as i8) - 1).collect()
}

fn rand_sign(rng: &mut Xoshiro256, n: usize) -> Vec<f32> {
    (0..n).map(|_| if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 }).collect()
}

fn main() {
    let mut rng = Xoshiro256::seed_from_u64(9);
    let adc = AdcConfig { bits: 0, full_scale: 1.0 };

    // Paper CIFAR head.
    let cifar = ImacFabric::build(
        &[
            (rand_tern(&mut rng, 1024 * 1024), 1024, 1024),
            (rand_tern(&mut rng, 1024 * 10), 1024, 10),
        ],
        &ImacConfig::default(),
        adc,
        1,
    );
    let macs_cifar = (1024 * 1024 + 1024 * 10) as f64;
    let x_cifar = rand_sign(&mut rng, 1024);

    // LeNet head.
    let lenet = ImacFabric::build(
        &[
            (rand_tern(&mut rng, 256 * 120), 256, 120),
            (rand_tern(&mut rng, 120 * 84), 120, 84),
            (rand_tern(&mut rng, 84 * 10), 84, 10),
        ],
        &ImacConfig::default(),
        adc,
        2,
    );
    let macs_lenet = (256 * 120 + 120 * 84 + 84 * 10) as f64;
    let x_lenet = rand_sign(&mut rng, 256);

    // Noisy CIFAR head (non-ideal path).
    let noisy_cfg = ImacConfig {
        crossbar: CrossbarConfig {
            device: DeviceConfig { sigma: 0.1, ..Default::default() },
            wire_alpha: 0.05,
            amp_offset_sigma: 0.01,
        },
        ..ImacConfig::default()
    };
    let cifar_noisy = ImacFabric::build(
        &[
            (rand_tern(&mut rng, 1024 * 1024), 1024, 1024),
            (rand_tern(&mut rng, 1024 * 10), 1024, 10),
        ],
        &noisy_cfg,
        adc,
        3,
    );

    let mut suite = BenchSuite::new("IMAC fabric forward (request hot path)");
    {
        let f = cifar;
        let x = x_cifar.clone();
        suite.bench_throughput("cifar_head 1024-1024-10 (ideal)", macs_cifar, move || {
            black_box(f.forward(&x)[0].to_bits() as u64)
        });
    }
    {
        let f = lenet;
        let x = x_lenet;
        suite.bench_throughput("lenet_head 256-120-84-10 (ideal)", macs_lenet, move || {
            black_box(f.forward(&x)[0].to_bits() as u64)
        });
    }
    {
        let f = cifar_noisy;
        let x = x_cifar;
        suite.bench_throughput("cifar_head (sigma=0.1, ir=0.05)", macs_cifar, move || {
            black_box(f.forward(&x)[0].to_bits() as u64)
        });
    }
    let results = suite.run_cli();
    for r in &results {
        if let Some(tput) = r.throughput_per_sec() {
            println!("{}: {:.2} GMAC/s", r.name, tput / 1e9);
        }
    }
}
