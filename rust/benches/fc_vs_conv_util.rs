//! The paper's §1 motivating claim: "poor performance and inefficient
//! hardware utilization of TPUs when executing FC layers compared to
//! convolutional layers" (their in-house Scale-Sim experiment). This bench
//! regenerates that comparison on our simulator: per-layer-class
//! utilization of the 32x32 OS array across the paper suite.

use tpu_imac::systolic::{simulate_network, ArrayConfig, Schedule, SramConfig};
use tpu_imac::util::bench::{black_box, BenchSuite};
use tpu_imac::util::table::{Align, Table};
use tpu_imac::workload::zoo;

fn main() {
    let cfg = ArrayConfig::default();
    let sram = SramConfig::default();
    let mut t = Table::new(&[
        "model", "conv util%", "dw util%", "fc util%", "fc/conv cycle share",
    ])
    .with_title("§1 claim — OS-array utilization by layer class")
    .with_aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
    for model in zoo::paper_suite() {
        let (recs, _) = simulate_network(&cfg, &sram, &model, Schedule::TpuOnly);
        let (mut cs, mut cc) = (0.0f64, 0u64); // conv util-weighted sum / cycles
        let (mut ds, mut dc) = (0.0f64, 0u64);
        let (mut fs, mut fc) = (0.0f64, 0u64);
        let mut conv_cycles = 0u64;
        for (r, l) in recs.iter().zip(&model.layers) {
            use tpu_imac::workload::LayerKind::*;
            match l.kind {
                Conv2d { .. } => {
                    cs += r.utilization * r.cycles as f64;
                    cc += r.cycles;
                    conv_cycles += r.cycles;
                }
                DepthwiseConv2d { .. } => {
                    ds += r.utilization * r.cycles as f64;
                    dc += r.cycles;
                    conv_cycles += r.cycles;
                }
                Dense { .. } => {
                    fs += r.utilization * r.cycles as f64;
                    fc += r.cycles;
                }
                _ => {}
            }
        }
        let pct = |s: f64, c: u64| {
            if c == 0 { "-".into() } else { format!("{:.1}", 100.0 * s / c as f64) }
        };
        t.row(vec![
            format!("{}/{}", model.name, model.dataset.label()),
            pct(cs, cc),
            pct(ds, dc),
            pct(fs, fc),
            format!("{:.2}", fc as f64 / conv_cycles.max(1) as f64),
        ]);
    }
    println!("{}", t.to_ascii());
    println!("(FC utilization ~1/32 of conv on the OS array: one output row per batch-1 GEMM.)");

    let mut suite = BenchSuite::new("per-layer simulation cost");
    let cfg2 = cfg;
    suite.bench("simulate lenet (TpuOnly)", move || {
        let m = zoo::lenet();
        let (_, s) = simulate_network(&cfg2, &SramConfig::default(), &m, Schedule::TpuOnly);
        black_box(s.total_cycles)
    });
    suite.run_cli();
}
