//! End-to-end serving bench: coordinator + native backend (rust conv +
//! IMAC fabric) on LeNet-class work. Uses trained weights when present,
//! otherwise a synthetic LeNet-shaped model, so `cargo bench` works before
//! `make train`.
//!
//! Two parts:
//!
//! 1. the historical max-batch sweep (plain prints, shapes unchanged);
//! 2. a `BenchSuite` trio — single-model registry vs **multi-model
//!    registry under mixed traffic** (2 deployments, distinct precisions,
//!    alternating `submit_to`) vs the **guarded single-model path** (every
//!    request carries a deadline budget, measuring the resilience layer's
//!    fault-free overhead) — so routing and resilience overheads are
//!    tracked series: `cargo bench --bench e2e_serving -- --json
//!    BENCH_hotpath.json` merges the suite into the same report the conv
//!    bench writes (existing suite/row names untouched).

use std::sync::Arc;
use std::time::Instant;

use tpu_imac::coordinator::{Coordinator, CoordinatorConfig, ModelRegistry, NativeBackend};
use tpu_imac::deploy::{Deployment, DeploymentSpec, SyntheticModel};
use tpu_imac::nn::{PrecisionPolicy, Tensor};
use tpu_imac::util::bench::BenchSuite;
use tpu_imac::util::rng::Xoshiro256;

/// Trained weights when present *and loadable*, else the synthetic zoo
/// LeNet (a truncated/corrupt artifact must not abort the bench). Built
/// once; the `Arc`-shared model is cloned into every backend/registry.
fn lenet_deployment() -> Deployment {
    let trained = DeploymentSpec::json_file("lenet", "artifacts/weights_lenet.json");
    match trained.build() {
        Ok(dep) => {
            eprintln!("using trained weights");
            dep
        }
        Err(_) => {
            eprintln!("no usable artifacts; using synthetic LeNet-shaped weights");
            DeploymentSpec::synthetic("lenet", SyntheticModel::Lenet, 5)
                .build()
                .expect("synthetic lenet deployment")
        }
    }
}

fn rand_image(rng: &mut Xoshiro256) -> Tensor {
    Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect())
}

fn main() {
    let n_requests: usize = std::env::var("TPU_IMAC_BENCH_FAST")
        .ok()
        .map(|_| 64)
        .unwrap_or(512);
    let lenet = lenet_deployment();

    for max_batch in [1usize, 8, 32] {
        let model = lenet.model.clone();
        let coord = Coordinator::start(
            CoordinatorConfig { max_batch, ..Default::default() },
            move || Box::new(NativeBackend::new(model)),
        );
        let client = coord.client();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(n_requests);
        for _ in 0..n_requests {
            rxs.push(client.submit(rand_image(&mut rng)).unwrap().1);
        }
        for rx in rxs {
            rx.recv().unwrap().expect("fault-free bench request");
        }
        let wall = t0.elapsed();
        let snap = coord.metrics.snapshot();
        println!(
            "max_batch={max_batch:>2}: {:.1} req/s | p50 {:.2} ms p95 {:.2} ms | {} batches | conv {:.0} ms imac {:.0} ms",
            n_requests as f64 / wall.as_secs_f64(),
            snap.p50_latency_us / 1e3,
            snap.p95_latency_us / 1e3,
            snap.batches,
            snap.conv_us_total as f64 / 1e3,
            snap.imac_us_total as f64 / 1e3,
        );
        coord.shutdown();
    }

    // Registry routing overhead: one deployment with plain submits vs two
    // deployments (fp32 LeNet + int8 dw-stack) under alternating tagged
    // traffic. Both rows measure a full submit→recv round of `wave`
    // requests through a live coordinator, so the delta is queue routing +
    // per-model backend resolution, not model arithmetic alone.
    let wave: usize = 32;
    let mut suite = BenchSuite::new("e2e serving: registry routing (mixed traffic)");
    {
        let registry = Arc::new(ModelRegistry::new());
        registry.register_built(lenet.clone()).expect("single registry");
        let coord = Coordinator::start_registry(
            CoordinatorConfig { max_batch: 8, ..Default::default() },
            registry,
        )
        .expect("start single-model registry");
        let client = coord.client();
        let mut rng = Xoshiro256::seed_from_u64(11);
        suite.bench_throughput("registry single-model (batch 8)", wave as f64, move || {
            // coord lives in the closure so the pool survives all samples.
            let _keepalive = &coord;
            let rxs: Vec<_> = (0..wave)
                .map(|_| client.submit(rand_image(&mut rng)).unwrap().1)
                .collect();
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().predicted as u64).sum()
        });
    }
    {
        let registry = Arc::new(ModelRegistry::new());
        registry.register_built(lenet.clone()).expect("two-model registry: lenet");
        registry
            .register(
                &DeploymentSpec::synthetic("mm", SyntheticModel::MobilenetMini, 6)
                    .precision(PrecisionPolicy::Int8),
            )
            .expect("two-model registry: mm");
        let coord = Coordinator::start_registry(
            CoordinatorConfig { max_batch: 8, ..Default::default() },
            registry,
        )
        .expect("start multi-model registry");
        let client = coord.client();
        let mut rng = Xoshiro256::seed_from_u64(11);
        suite.bench_throughput(
            "registry multi-model mixed (2 deployments, batch 8)",
            wave as f64,
            move || {
                let _keepalive = &coord;
                let rxs: Vec<_> = (0..wave)
                    .map(|i| {
                        let name = if i % 2 == 0 { "lenet" } else { "mm" };
                        client.submit_to(name, rand_image(&mut rng)).unwrap().1
                    })
                    .collect();
                rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().predicted as u64).sum()
            },
        );
    }
    {
        // Guarded-path overhead: the same single-model wave, but every
        // request carries a (generous) deadline budget — measuring what
        // the resilience layer (deadline bookkeeping, admission check,
        // supervised worker loop) costs on a fault-free run.
        let registry = Arc::new(ModelRegistry::new());
        registry.register_built(lenet.clone()).expect("guarded registry");
        let coord = Coordinator::start_registry(
            CoordinatorConfig { max_batch: 8, ..Default::default() },
            registry,
        )
        .expect("start guarded single-model registry");
        let client = coord.client();
        let mut rng = Xoshiro256::seed_from_u64(11);
        suite.bench_throughput(
            "registry single-model guarded (deadline budget, batch 8)",
            wave as f64,
            move || {
                let _keepalive = &coord;
                let rxs: Vec<_> = (0..wave)
                    .map(|_| {
                        client
                            .submit_within(rand_image(&mut rng), std::time::Duration::from_secs(30))
                            .unwrap()
                            .1
                    })
                    .collect();
                rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().predicted as u64).sum()
            },
        );
    }
    let results = suite.run_cli();
    let mean = |name: &str| {
        results
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("bench row '{name}' missing"))
            .mean_ns
    };
    let single = mean("registry single-model (batch 8)");
    let multi = mean("registry multi-model mixed (2 deployments, batch 8)");
    let guarded = mean("registry single-model guarded (deadline budget, batch 8)");
    println!(
        "registry routing: single {:.2} ms/wave vs mixed 2-model {:.2} ms/wave ({:.2}x)",
        single / 1e6,
        multi / 1e6,
        multi / single
    );
    println!(
        "resilience overhead: guarded {:.2} ms/wave vs plain {:.2} ms/wave ({:+.1}%)",
        guarded / 1e6,
        single / 1e6,
        (guarded / single - 1.0) * 100.0
    );
}
