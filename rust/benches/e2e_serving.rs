//! End-to-end serving bench: coordinator + native backend (rust conv +
//! IMAC fabric) on LeNet-class work. Uses trained weights when present,
//! otherwise a synthetic LeNet-shaped model, so `cargo bench` works before
//! `make train`.
//!
//! Three parts:
//!
//! 1. the historical max-batch sweep (plain prints, shapes unchanged);
//! 2. a `BenchSuite` trio — single-model registry vs **multi-model
//!    registry under mixed traffic** (2 deployments, distinct precisions,
//!    alternating `submit_to`) vs the **guarded single-model path** (every
//!    request carries a deadline budget, measuring the resilience layer's
//!    fault-free overhead) — so routing and resilience overheads are
//!    tracked series: `cargo bench --bench e2e_serving -- --json
//!    BENCH_hotpath.json` merges the suite into the same report the conv
//!    bench writes (existing suite/row names untouched);
//! 3. a **sustained-load soak**: a flooding tenant plus a weighted,
//!    deadline-guarded background tenant under concurrent hot swaps,
//!    driving `TPU_IMAC_SOAK_REQUESTS` mixed-model requests (default
//!    200k, 2k under `TPU_IMAC_BENCH_FAST=1`) through the weighted
//!    scheduler, then emitting p50/p95/p99 latency and worst-tenant p95
//!    queue-wait rows into the same `--json` report (new suite name; the
//!    frozen rows above are untouched).

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use tpu_imac::coordinator::{
    Coordinator, CoordinatorConfig, ModelRegistry, NativeBackend, SchedPolicy,
};
use tpu_imac::deploy::{Deployment, DeploymentSpec, SyntheticModel};
use tpu_imac::nn::{PrecisionPolicy, Tensor};
use tpu_imac::serve_http::{HttpConfig, HttpServer};
use tpu_imac::util::bench::{json_path_from_args, write_json, BenchResult, BenchSuite};
use tpu_imac::util::rng::Xoshiro256;

/// Trained weights when present *and loadable*, else the synthetic zoo
/// LeNet (a truncated/corrupt artifact must not abort the bench). Built
/// once; the `Arc`-shared model is cloned into every backend/registry.
fn lenet_deployment() -> Deployment {
    let trained = DeploymentSpec::json_file("lenet", "artifacts/weights_lenet.json");
    match trained.build() {
        Ok(dep) => {
            eprintln!("using trained weights");
            dep
        }
        Err(_) => {
            eprintln!("no usable artifacts; using synthetic LeNet-shaped weights");
            DeploymentSpec::synthetic("lenet", SyntheticModel::Lenet, 5)
                .build()
                .expect("synthetic lenet deployment")
        }
    }
}

fn rand_image(rng: &mut Xoshiro256) -> Tensor {
    Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect())
}

/// Read one `Content-Length`-framed HTTP response and return its
/// `"predicted"` field (panics on any non-200 — the bench is fault-free).
fn read_predicted(stream: &mut std::net::TcpStream) -> u64 {
    use std::io::Read;
    let mut buf = Vec::with_capacity(1024);
    let head_end = loop {
        if let Some(i) = buf.windows(4).position(|w| w == b"\r\n\r\n") {
            break i + 4;
        }
        let mut chunk = [0u8; 1024];
        let n = stream.read(&mut chunk).expect("read http response");
        assert!(n > 0, "server closed mid-response");
        buf.extend_from_slice(&chunk[..n]);
    };
    let head = std::str::from_utf8(&buf[..head_end]).expect("ascii head");
    assert!(head.starts_with("HTTP/1.1 200"), "bench request failed: {head}");
    let content_length: usize = head
        .lines()
        .find_map(|l| l.strip_prefix("Content-Length: "))
        .and_then(|v| v.parse().ok())
        .expect("content-length");
    while buf.len() < head_end + content_length {
        let mut chunk = [0u8; 1024];
        let n = stream.read(&mut chunk).expect("read http body");
        assert!(n > 0, "server closed mid-body");
        buf.extend_from_slice(&chunk[..n]);
    }
    let body = std::str::from_utf8(&buf[head_end..head_end + content_length]).expect("utf8 body");
    let tail = body.split("\"predicted\":").nth(1).expect("predicted field");
    tail.chars()
        .take_while(char::is_ascii_digit)
        .collect::<String>()
        .parse()
        .expect("predicted digits")
}

fn main() {
    let n_requests: usize = std::env::var("TPU_IMAC_BENCH_FAST")
        .ok()
        .map(|_| 64)
        .unwrap_or(512);
    let lenet = lenet_deployment();

    for max_batch in [1usize, 8, 32] {
        let model = lenet.model.clone();
        let coord = Coordinator::start(
            CoordinatorConfig { max_batch, ..Default::default() },
            move || Box::new(NativeBackend::new(model)),
        );
        let client = coord.client();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(n_requests);
        for _ in 0..n_requests {
            rxs.push(client.submit(rand_image(&mut rng)).unwrap().1);
        }
        for rx in rxs {
            rx.recv().unwrap().expect("fault-free bench request");
        }
        let wall = t0.elapsed();
        let snap = coord.metrics.snapshot();
        println!(
            "max_batch={max_batch:>2}: {:.1} req/s | p50 {:.2} ms p95 {:.2} ms | {} batches | conv {:.0} ms imac {:.0} ms",
            n_requests as f64 / wall.as_secs_f64(),
            snap.p50_latency_us / 1e3,
            snap.p95_latency_us / 1e3,
            snap.batches,
            snap.conv_us_total as f64 / 1e3,
            snap.imac_us_total as f64 / 1e3,
        );
        coord.shutdown();
    }

    // Registry routing overhead: one deployment with plain submits vs two
    // deployments (fp32 LeNet + int8 dw-stack) under alternating tagged
    // traffic. Both rows measure a full submit→recv round of `wave`
    // requests through a live coordinator, so the delta is queue routing +
    // per-model backend resolution, not model arithmetic alone.
    let wave: usize = 32;
    let mut suite = BenchSuite::new("e2e serving: registry routing (mixed traffic)");
    {
        let registry = Arc::new(ModelRegistry::new());
        registry.register_built(lenet.clone()).expect("single registry");
        let coord = Coordinator::start_registry(
            CoordinatorConfig { max_batch: 8, ..Default::default() },
            registry,
        )
        .expect("start single-model registry");
        let client = coord.client();
        let mut rng = Xoshiro256::seed_from_u64(11);
        suite.bench_throughput("registry single-model (batch 8)", wave as f64, move || {
            // coord lives in the closure so the pool survives all samples.
            let _keepalive = &coord;
            let rxs: Vec<_> = (0..wave)
                .map(|_| client.submit(rand_image(&mut rng)).unwrap().1)
                .collect();
            rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().predicted as u64).sum()
        });
    }
    {
        let registry = Arc::new(ModelRegistry::new());
        registry.register_built(lenet.clone()).expect("two-model registry: lenet");
        registry
            .register(
                &DeploymentSpec::synthetic("mm", SyntheticModel::MobilenetMini, 6)
                    .precision(PrecisionPolicy::Int8),
            )
            .expect("two-model registry: mm");
        let coord = Coordinator::start_registry(
            CoordinatorConfig { max_batch: 8, ..Default::default() },
            registry,
        )
        .expect("start multi-model registry");
        let client = coord.client();
        let mut rng = Xoshiro256::seed_from_u64(11);
        suite.bench_throughput(
            "registry multi-model mixed (2 deployments, batch 8)",
            wave as f64,
            move || {
                let _keepalive = &coord;
                let rxs: Vec<_> = (0..wave)
                    .map(|i| {
                        let name = if i % 2 == 0 { "lenet" } else { "mm" };
                        client.submit_to(name, rand_image(&mut rng)).unwrap().1
                    })
                    .collect();
                rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().predicted as u64).sum()
            },
        );
    }
    {
        // Guarded-path overhead: the same single-model wave, but every
        // request carries a (generous) deadline budget — measuring what
        // the resilience layer (deadline bookkeeping, admission check,
        // supervised worker loop) costs on a fault-free run.
        let registry = Arc::new(ModelRegistry::new());
        registry.register_built(lenet.clone()).expect("guarded registry");
        let coord = Coordinator::start_registry(
            CoordinatorConfig { max_batch: 8, ..Default::default() },
            registry,
        )
        .expect("start guarded single-model registry");
        let client = coord.client();
        let mut rng = Xoshiro256::seed_from_u64(11);
        suite.bench_throughput(
            "registry single-model guarded (deadline budget, batch 8)",
            wave as f64,
            move || {
                let _keepalive = &coord;
                let rxs: Vec<_> = (0..wave)
                    .map(|_| {
                        client
                            .submit_within(rand_image(&mut rng), std::time::Duration::from_secs(30))
                            .unwrap()
                            .1
                    })
                    .collect();
                rxs.into_iter().map(|rx| rx.recv().unwrap().unwrap().predicted as u64).sum()
            },
        );
    }
    {
        // HTTP front-end overhead: the same single-model wave, but over
        // the wire — 8 warmed persistent connections (so batch formation
        // matches the in-process concurrency), full request-format →
        // scan → submit → response-format round trip per request. The
        // delta vs "registry single-model (batch 8)" is the whole wire
        // layer: framing, JSON scan, TCP. New row; frozen rows untouched.
        let registry = Arc::new(ModelRegistry::new());
        registry.register_built(lenet.clone()).expect("http registry");
        let coord = Coordinator::start_registry(
            CoordinatorConfig { max_batch: 8, ..Default::default() },
            Arc::clone(&registry),
        )
        .expect("start http-bench registry");
        let server = HttpServer::start(
            HttpConfig { addr: "127.0.0.1:0".to_string(), ..Default::default() },
            coord.client(),
            registry,
            Arc::clone(&coord.metrics),
        )
        .expect("start http-bench server");
        let addr = server.addr();
        let conns = 8usize;
        let mut rng = Xoshiro256::seed_from_u64(11);
        // Pre-format distinct request buffers (cycled), outside timing.
        let requests: Vec<Vec<u8>> = (0..4)
            .map(|_| {
                let img = rand_image(&mut rng);
                let mut body = String::from("{\"model\":\"lenet\",\"image\":[");
                for (i, v) in img.data.iter().enumerate() {
                    if i > 0 {
                        body.push(',');
                    }
                    body.push_str(&format!("{v}"));
                }
                body.push_str("],\"timeout_ms\":30000}");
                format!(
                    "POST /v1/infer HTTP/1.1\r\nHost: bench\r\nContent-Length: {}\r\n\r\n{body}",
                    body.len()
                )
                .into_bytes()
            })
            .collect();
        let mut streams: Vec<std::net::TcpStream> = (0..conns)
            .map(|_| std::net::TcpStream::connect(addr).expect("connect http bench"))
            .collect();
        let per_conn = wave / conns;
        suite.bench_throughput(
            "http infer round-trip (batch 8, persistent conn)",
            wave as f64,
            move || {
                let _keepalive = (&coord, &server);
                let requests = &requests;
                std::thread::scope(|s| {
                    let handles: Vec<_> = streams
                        .iter_mut()
                        .enumerate()
                        .map(|(t, stream)| {
                            s.spawn(move || {
                                let mut sum = 0u64;
                                for i in 0..per_conn {
                                    let req = &requests[(t + i) % requests.len()];
                                    std::io::Write::write_all(stream, req)
                                        .expect("write http request");
                                    sum += read_predicted(stream);
                                }
                                sum
                            })
                        })
                        .collect();
                    handles.into_iter().map(|h| h.join().expect("http bench conn")).sum()
                })
            },
        );
    }
    let results = suite.run_cli();
    let mean = |name: &str| {
        results
            .iter()
            .find(|r| r.name == name)
            .unwrap_or_else(|| panic!("bench row '{name}' missing"))
            .mean_ns
    };
    let single = mean("registry single-model (batch 8)");
    let multi = mean("registry multi-model mixed (2 deployments, batch 8)");
    let guarded = mean("registry single-model guarded (deadline budget, batch 8)");
    let http = mean("http infer round-trip (batch 8, persistent conn)");
    println!(
        "registry routing: single {:.2} ms/wave vs mixed 2-model {:.2} ms/wave ({:.2}x)",
        single / 1e6,
        multi / 1e6,
        multi / single
    );
    println!(
        "resilience overhead: guarded {:.2} ms/wave vs plain {:.2} ms/wave ({:+.1}%)",
        guarded / 1e6,
        single / 1e6,
        (guarded / single - 1.0) * 100.0
    );
    println!(
        "http wire overhead: {:.2} ms/wave over 8 persistent conns vs in-process {:.2} ms/wave ({:.2}x)",
        http / 1e6,
        single / 1e6,
        http / single
    );

    run_soak();
}

/// Sustained-load soak: a flooding tenant (fire-and-forget, retried
/// through admission sheds) plus a weight-2 deadline-guarded background
/// tenant, served by the weighted scheduler while a third thread hot-swaps
/// the background deployment (alternating weights, so re-derivation is
/// exercised live). Completion is observed through the metrics counters —
/// every accepted request must be completed or answered with a typed drop —
/// so the soak doubles as a zero-lost-replies check at scale.
fn run_soak() {
    let fast = std::env::var("TPU_IMAC_BENCH_FAST").as_deref() == Ok("1");
    let total: u64 = std::env::var("TPU_IMAC_SOAK_REQUESTS")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(if fast { 2_000 } else { 200_000 });

    let registry = Arc::new(ModelRegistry::new());
    registry
        .register(
            &DeploymentSpec::synthetic("flood", SyntheticModel::Lenet, 5).queue_quota(256),
        )
        .expect("soak: flood deployment");
    let bg_spec = |weight: usize| {
        DeploymentSpec::synthetic("bg", SyntheticModel::MobilenetMini, 6)
            .precision(PrecisionPolicy::Int8)
            .queue_quota(64)
            .weight(weight)
    };
    registry.register(&bg_spec(2)).expect("soak: bg deployment");
    let coord = Coordinator::start_registry(
        CoordinatorConfig {
            max_batch: 8,
            workers: 2,
            batch_timeout: Duration::from_micros(200),
            scheduling: SchedPolicy::Weighted,
            ..Default::default()
        },
        registry.clone(),
    )
    .expect("soak: start registry coordinator");

    let accepted = Arc::new(AtomicU64::new(0));
    let stop = Arc::new(AtomicBool::new(false));

    // Flooding tenant: ~3/4 of all traffic, receivers dropped on purpose.
    let flood_n = total * 3 / 4;
    let flooder = {
        let client = coord.client();
        let accepted = accepted.clone();
        std::thread::spawn(move || {
            let mut rng = Xoshiro256::seed_from_u64(0x50AC);
            let mut sent = 0u64;
            while sent < flood_n {
                if client.submit_to("flood", rand_image(&mut rng)).is_ok() {
                    sent += 1;
                    accepted.fetch_add(1, Ordering::Relaxed);
                } else {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
        })
    };

    // Background tenant: every request carries a deadline budget.
    let bg_n = total - flood_n;
    let bg = {
        let client = coord.client();
        let accepted = accepted.clone();
        std::thread::spawn(move || {
            let mut rng = Xoshiro256::seed_from_u64(0x50AD);
            let budget = Duration::from_secs(10);
            let mut sent = 0u64;
            while sent < bg_n {
                if client.submit_to_within("bg", rand_image(&mut rng), budget).is_ok() {
                    sent += 1;
                    accepted.fetch_add(1, Ordering::Relaxed);
                } else {
                    std::thread::sleep(Duration::from_micros(50));
                }
            }
        })
    };

    // Concurrent hot swaps flip the background tenant's weight 2↔3; the
    // scheduler must pick the new share up without dropping a request.
    let swapper = {
        let registry = registry.clone();
        let stop = stop.clone();
        std::thread::spawn(move || {
            let mut flip = false;
            while !stop.load(Ordering::Relaxed) {
                std::thread::sleep(Duration::from_millis(50));
                registry
                    .swap("bg", &bg_spec(if flip { 3 } else { 2 }))
                    .expect("soak: bg swap");
                flip = !flip;
            }
        })
    };

    let t0 = Instant::now();
    flooder.join().unwrap();
    bg.join().unwrap();
    // Receivers are dropped, so completion is observed via the counters:
    // every accepted request ends as completed, faulted or deadline-dropped.
    let target = accepted.load(Ordering::Relaxed);
    let snap = loop {
        let snap = coord.metrics.snapshot();
        if snap.completed + snap.deadline_drops + snap.faulted >= target {
            break snap;
        }
        assert!(
            t0.elapsed() < Duration::from_secs(600),
            "soak stalled: {}/{} answered after 600s",
            snap.completed + snap.deadline_drops + snap.faulted,
            target
        );
        std::thread::sleep(Duration::from_millis(2));
    };
    let wall = t0.elapsed();
    stop.store(true, Ordering::Relaxed);
    swapper.join().unwrap();
    let hist_kib = coord.metrics.histogram_footprint_bytes() / 1024;
    coord.shutdown();

    let worst_wait_us =
        snap.models.iter().map(|m| m.p95_queue_wait_us).fold(0.0f64, f64::max);
    println!(
        "soak: {} requests in {:.2}s ({:.0} req/s, {} deadline drops)",
        target,
        wall.as_secs_f64(),
        target as f64 / wall.as_secs_f64(),
        snap.deadline_drops,
    );
    println!(
        "soak latency: p50 {:.2} ms p95 {:.2} ms p99 {:.2} ms | worst-tenant p95 wait {:.2} ms",
        snap.p50_latency_us / 1e3,
        snap.p95_latency_us / 1e3,
        snap.p99_latency_us / 1e3,
        worst_wait_us / 1e3,
    );
    println!(
        "soak batch closes: full {} shallow {} deadline {} timeout {} | histograms {} KiB",
        snap.batch_close_full,
        snap.batch_close_shallow,
        snap.batch_close_deadline,
        snap.batch_close_timeout,
        hist_kib,
    );

    let row = |name: &str, us: f64| BenchResult {
        name: name.to_string(),
        iters: target,
        mean_ns: us * 1e3,
        median_ns: us * 1e3,
        p95_ns: us * 1e3,
        items_per_iter: None,
    };
    let rows = [
        row("soak mixed-tenant p50 latency", snap.p50_latency_us),
        row("soak mixed-tenant p95 latency", snap.p95_latency_us),
        row("soak mixed-tenant p99 latency", snap.p99_latency_us),
        row("soak worst-tenant p95 queue wait", worst_wait_us),
    ];
    if let Some(path) = json_path_from_args(std::env::args().skip(1)) {
        match write_json(&path, "e2e serving: sustained soak (weighted scheduling)", &rows) {
            Ok(()) => eprintln!("soak results appended to {path}"),
            Err(e) => eprintln!("failed to write {path}: {e}"),
        }
    }
}
