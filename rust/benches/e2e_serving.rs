//! End-to-end serving bench: coordinator + native backend (rust conv +
//! IMAC fabric) on LeNet-class work. Uses trained weights when present,
//! otherwise a synthetic LeNet-shaped model, so `cargo bench` works before
//! `make train`.

use std::time::Instant;

use tpu_imac::coordinator::{Coordinator, CoordinatorConfig, NativeBackend};
use tpu_imac::imac::{AdcConfig, ImacConfig};
use tpu_imac::nn::synthetic::lenet_weights_doc;
use tpu_imac::nn::{DeployedModel, Tensor};
use tpu_imac::util::rng::Xoshiro256;

fn load_model() -> DeployedModel {
    let imac = ImacConfig::default();
    let adc = AdcConfig { bits: 0, full_scale: 1.0 };
    if let Ok(m) = DeployedModel::load("artifacts/weights_lenet.json", &imac, adc, 0) {
        eprintln!("using trained weights");
        return m;
    }
    eprintln!("no artifacts; using synthetic LeNet-shaped weights");
    let mut rng = Xoshiro256::seed_from_u64(5);
    DeployedModel::from_json(&lenet_weights_doc(&mut rng), &imac, adc, 0).expect("synthetic")
}

fn main() {
    let n_requests: usize = std::env::var("TPU_IMAC_BENCH_FAST")
        .ok()
        .map(|_| 64)
        .unwrap_or(512);

    for max_batch in [1usize, 8, 32] {
        let coord = Coordinator::start(
            CoordinatorConfig { max_batch, ..Default::default() },
            || Box::new(NativeBackend::new(load_model())),
        );
        let client = coord.client();
        let mut rng = Xoshiro256::seed_from_u64(7);
        let t0 = Instant::now();
        let mut rxs = Vec::with_capacity(n_requests);
        for _ in 0..n_requests {
            let img = Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect());
            rxs.push(client.submit(img).unwrap().1);
        }
        for rx in rxs {
            rx.recv().unwrap();
        }
        let wall = t0.elapsed();
        let snap = coord.metrics.snapshot();
        println!(
            "max_batch={max_batch:>2}: {:.1} req/s | p50 {:.2} ms p95 {:.2} ms | {} batches | conv {:.0} ms imac {:.0} ms",
            n_requests as f64 / wall.as_secs_f64(),
            snap.p50_latency_us / 1e3,
            snap.p95_latency_us / 1e3,
            snap.batches,
            snap.conv_us_total as f64 / 1e3,
            snap.imac_us_total as f64 / 1e3,
        );
        coord.shutdown();
    }
}
