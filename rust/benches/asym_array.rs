//! Ablation for the paper's §1 remark: "systolic arrays have a symmetrical
//! size to optimize Convolutional layer execution. However, if designed
//! with asymmetric dimensions, they can accelerate FC operations at the
//! cost of convolutional layer execution performance."
//!
//! We sweep array aspect ratios at constant PE budget (1024 PEs) and report
//! conv-only vs FC-only vs total cycles for each model — quantifying the
//! trade the TPU-IMAC integration dissolves (FC leaves the array entirely).

use tpu_imac::systolic::{simulate_network, ArrayConfig, Schedule, SramConfig};
use tpu_imac::util::table::{Align, Table};
use tpu_imac::workload::zoo;

fn main() {
    let shapes: [(usize, usize); 5] = [(128, 8), (64, 16), (32, 32), (16, 64), (8, 128)];
    let sram = SramConfig::default();
    for model in [zoo::lenet(), zoo::mobilenet_v1(tpu_imac::workload::Dataset::Cifar10)] {
        let mut t = Table::new(&["array", "conv kcyc", "fc kcyc", "total kcyc", "vs 32x32"])
            .with_title(&format!(
                "{} — aspect-ratio sweep at 1024 PEs (TPU-only schedule)",
                model.name
            ))
            .with_aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right]);
        let mut base_total = 0.0;
        let mut rows = Vec::new();
        for (r, c) in shapes {
            let cfg = ArrayConfig { rows: r, cols: c, ..ArrayConfig::default() };
            let (recs, stats) = simulate_network(&cfg, &sram, &model, Schedule::TpuOnly);
            let fc: u64 = recs
                .iter()
                .zip(&model.layers)
                .filter(|(_, l)| l.is_dense())
                .map(|(rec, _)| rec.cycles)
                .sum();
            let conv = stats.total_cycles - fc;
            if (r, c) == (32, 32) {
                base_total = stats.total_cycles as f64;
            }
            rows.push((format!("{r}x{c}"), conv, fc, stats.total_cycles));
        }
        for (name, conv, fc, total) in rows {
            t.row(vec![
                name,
                format!("{:.3}", conv as f64 / 1e3),
                format!("{:.3}", fc as f64 / 1e3),
                format!("{:.3}", total as f64 / 1e3),
                format!("{:+.1}%", (total as f64 / base_total - 1.0) * 100.0),
            ]);
        }
        println!("{}", t.to_ascii());
    }
    println!(
        "Wide arrays (many cols) cut batch-1 FC cycles (more output columns per fold)\n\
         but inflate conv cycles (fewer ofmap rows per fold) — the trade the paper's\n\
         IMAC offload removes: with TPU-IMAC, FC costs 1 cycle/layer regardless."
    );
}
