//! `tpu-imac-lint` — in-repo invariant linter for the TPU-IMAC reproduction.
//!
//! Dependency-free static analysis over `rust/src`, `rust/tests`,
//! `rust/benches`, and the docs. Seven rules, each anchored to `file:line`:
//!
//! 1. `unsafe-safety`   — every `unsafe` has an immediately preceding
//!    `// SAFETY:` comment (attributes may interleave).
//! 2. `taxonomy-sync`   — the `ServeError` enum, the `serve_error_parts`
//!    status match, the router module-doc table, and the README taxonomy
//!    table agree on variant names and statuses.
//! 3. `bench-rows`      — frozen `BENCH_hotpath.json` row names (manifest:
//!    `rust/lint/frozen_bench_rows.txt`) appear verbatim in bench sources.
//! 4. `metrics-surface` — every `Metrics` counter is read by `fn snapshot`;
//!    every `Snapshot` field is a `to_json` key and appears in the serve
//!    summary printed by `main.rs`.
//! 5. `config-docs`     — every config key parsed in `config/mod.rs` is
//!    documented in the README.
//! 6. `hotpath-alloc`   — alloc-prone constructs are forbidden on hot-path
//!    modules outside `// lint: allow(alloc)` regions.
//! 7. `flag-ordering`   — `Ordering::Relaxed` on cross-thread control flags
//!    (shutdown/drain/generation) is rejected.
//!
//! Usage: `cargo run -p tpu-imac-lint [-- <repo-root>]`. Without an argument
//! the repo root is found by walking up from the current directory. Exits 0
//! when clean, 1 when any rule fires, 2 on usage/setup errors.

mod rules;
mod scan;

use std::fs;
use std::path::{Path, PathBuf};

use rules::{Finding, FLAG_ALLOWLIST};
use scan::{parse_with_raw, SourceFile};

/// Modules whose steady state must not allocate (rule 6). Matched by
/// path suffix against `rust/src`.
const HOT_PATHS: [&str; 5] = [
    "nn/gemm.rs",
    "nn/simd.rs",
    "imac/crossbar.rs",
    "serve_http/conn.rs",
    "serve_http/scanner.rs",
];

fn main() {
    let mut args = std::env::args().skip(1);
    let root = match args.next() {
        Some(s) if s == "--help" || s == "-h" => {
            print_help();
            return;
        }
        Some(p) => PathBuf::from(p),
        None => match find_root() {
            Some(r) => r,
            None => {
                eprintln!("tpu-imac-lint: could not locate the repo root (rust/src + README.md)");
                std::process::exit(2);
            }
        },
    };
    match run(&root) {
        Ok(findings) => {
            for f in &findings {
                println!("{f}");
            }
            if findings.is_empty() {
                println!("tpu-imac-lint: clean (7 rules)");
            } else {
                eprintln!("tpu-imac-lint: {} finding(s)", findings.len());
                std::process::exit(1);
            }
        }
        Err(e) => {
            eprintln!("tpu-imac-lint: {e}");
            std::process::exit(2);
        }
    }
}

fn print_help() {
    println!("tpu-imac-lint [repo-root]");
    println!("Runs the repo invariant rules; exits non-zero on any finding.");
}

/// Walk up from the current directory to the checkout root.
fn find_root() -> Option<PathBuf> {
    let mut dir = std::env::current_dir().ok()?;
    loop {
        if dir.join("rust/src").is_dir() && dir.join("README.md").is_file() {
            return Some(dir);
        }
        if !dir.pop() {
            return None;
        }
    }
}

/// Recursively collect `.rs` files under `dir`, sorted for determinism.
fn rust_files(dir: &Path, out: &mut Vec<PathBuf>) {
    let Ok(entries) = fs::read_dir(dir) else { return };
    let mut paths: Vec<PathBuf> = entries.flatten().map(|e| e.path()).collect();
    paths.sort();
    for p in paths {
        if p.is_dir() {
            rust_files(&p, out);
        } else if p.extension().map(|e| e == "rs").unwrap_or(false) {
            out.push(p);
        }
    }
}

/// Path relative to the repo root, with forward slashes, for findings.
fn rel(root: &Path, p: &Path) -> String {
    p.strip_prefix(root).unwrap_or(p).to_string_lossy().replace('\\', "/")
}

fn read(path: &Path) -> Result<String, String> {
    fs::read_to_string(path).map_err(|e| format!("{}: {e}", path.display()))
}

fn run(root: &Path) -> Result<Vec<Finding>, String> {
    let mut files = Vec::new();
    for sub in ["rust/src", "rust/tests", "rust/benches"] {
        rust_files(&root.join(sub), &mut files);
    }
    if files.is_empty() {
        return Err(format!("no .rs files under {}", root.display()));
    }

    let mut parsed: Vec<SourceFile> = Vec::new();
    for p in &files {
        parsed.push(parse_with_raw(&rel(root, p), &read(p)?));
    }

    let readme = read(&root.join("README.md"))?;
    let mut findings: Vec<Finding> = Vec::new();

    // Rule 1 over every Rust file; rules 6/7 over their scoped subsets.
    for f in &parsed {
        findings.extend(rules::rule_unsafe_safety(f));
        if HOT_PATHS.iter().any(|h| f.path.ends_with(h)) {
            findings.extend(rules::rule_hotpath_alloc(f));
        }
        if f.path.starts_with("rust/src") {
            findings.extend(rules::rule_flag_ordering(f, &FLAG_ALLOWLIST));
        }
    }

    // Rule 2: the four-way ServeError taxonomy.
    let coord = parsed.iter().find(|f| f.path.ends_with("coordinator/mod.rs"));
    let router = parsed.iter().find(|f| f.path.ends_with("serve_http/router.rs"));
    match (coord, router) {
        (Some(c), Some(r)) => findings.extend(rules::rule_taxonomy(c, r, "README.md", &readme)),
        _ => return Err("coordinator/mod.rs or serve_http/router.rs not found".into()),
    }

    // Rule 3: frozen bench rows.
    let manifest_path = root.join("rust/lint/frozen_bench_rows.txt");
    let manifest = read(&manifest_path)?;
    let benches: Vec<(String, String)> = parsed
        .iter()
        .filter(|f| f.path.starts_with("rust/benches"))
        .map(|f| {
            let text: Vec<String> = f.lines.iter().map(|l| l.raw.clone()).collect();
            (f.path.clone(), text.join("\n"))
        })
        .collect();
    findings.extend(rules::rule_bench_rows("rust/lint/frozen_bench_rows.txt", &manifest, &benches));

    // Rule 4: metrics plumbed end to end.
    let metrics = parsed.iter().find(|f| f.path.ends_with("metrics/mod.rs"));
    let main_src = parsed.iter().find(|f| f.path.ends_with("src/main.rs"));
    match (metrics, main_src) {
        (Some(m), Some(s)) => findings.extend(rules::rule_metrics_surface(m, s)),
        _ => return Err("metrics/mod.rs or src/main.rs not found".into()),
    }

    // Rule 5: config keys documented.
    match parsed.iter().find(|f| f.path.ends_with("config/mod.rs")) {
        Some(c) => findings.extend(rules::rule_config_docs(c, "README.md", &readme)),
        None => return Err("config/mod.rs not found".into()),
    }

    findings.sort_by(|a, b| (&a.file, a.line).cmp(&(&b.file, b.line)));
    Ok(findings)
}
