//! Line/token scanner shared by every lint rule.
//!
//! The scanner splits a Rust source file into per-line views:
//!
//! * `raw`     — the line exactly as written (used where string literals
//!   matter, e.g. extracting `"DeadlineExceeded"` from a match arm),
//! * `code`    — the line with comments stripped and string/char literal
//!   *contents* blanked to spaces (delimiters kept), so token searches never
//!   match inside literals or comments,
//! * `comment` — the text of any comment on the line (`//`, `///`, `//!`,
//!   and `/* .. */` interiors), without the comment markers.
//!
//! On top of that it tracks two kinds of exemption region:
//!
//! * `#[cfg(test)] mod … { … }` bodies (brace-counted on the `code` view),
//!   so rules can skip test code, and
//! * `// lint: allow(TAG)` … `// lint: end-allow(TAG)` regions plus
//!   trailing `// lint: allow(TAG)` single-line waivers.

/// One source line with its comment-aware views and exemption state.
pub struct Line {
    /// The line exactly as read from disk (no trailing newline).
    pub raw: String,
    /// Comments stripped, string/char contents blanked to spaces.
    pub code: String,
    /// Comment text on this line (without `//` / `/*` markers).
    pub comment: String,
    /// True when the line sits inside a `#[cfg(test)] mod … { … }` body.
    pub in_test: bool,
    /// `lint: allow(TAG)` region tags active on this line.
    region_allows: Vec<String>,
    /// Tags from a trailing `// lint: allow(TAG)` on this very line.
    line_allows: Vec<String>,
}

impl Line {
    /// True when `tag` is waived for this line, either by an enclosing
    /// `lint: allow(tag)` region or a trailing same-line annotation.
    pub fn allowed(&self, tag: &str) -> bool {
        self.region_allows.iter().any(|t| t == tag) || self.line_allows.iter().any(|t| t == tag)
    }
}

/// A scanned file: path (as reported in findings) plus per-line views.
pub struct SourceFile {
    pub path: String,
    pub lines: Vec<Line>,
}

impl SourceFile {
    /// Scan `text` (the file contents) into per-line code/comment views.
    pub fn parse(path: &str, text: &str) -> SourceFile {
        let mut lines = split_views(text);
        mark_test_mods(&mut lines);
        mark_allow_regions(&mut lines);
        SourceFile { path: path.to_string(), lines }
    }

    /// 1-based line number for an index into `lines`.
    pub fn lineno(&self, idx: usize) -> usize {
        idx + 1
    }
}

/// Tokenizer state across characters.
enum St {
    Code,
    LineComment,
    BlockComment(u32),
    Str,
    RawStr(u32),
    CharLit,
}

fn is_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

/// Split the file into lines, separating code from comments and blanking
/// string/char literal contents in the `code` view.
fn split_views(text: &str) -> Vec<Line> {
    let chars: Vec<char> = text.chars().collect();
    let mut lines = Vec::new();
    let mut code = String::new();
    let mut comment = String::new();
    let mut st = St::Code;
    let mut i = 0usize;
    while i < chars.len() {
        let c = chars[i];
        if c == '\n' {
            lines.push(new_line(&code, &comment));
            code.clear();
            comment.clear();
            if matches!(st, St::LineComment) {
                st = St::Code;
            }
            i += 1;
            continue;
        }
        match st {
            St::Code => {
                let next = chars.get(i + 1).copied();
                if c == '/' && next == Some('/') {
                    st = St::LineComment;
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment(1);
                    code.push(' ');
                    i += 2;
                } else if c == '"' {
                    code.push('"');
                    st = St::Str;
                    i += 1;
                } else if c == 'r' && !code.ends_with(is_ident) {
                    if let Some(h) = raw_str_hashes(&chars, i) {
                        code.push('"');
                        st = St::RawStr(h);
                        i += 2 + h as usize; // r, hashes, opening quote
                    } else {
                        code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Distinguish char literals from lifetimes.
                    let n1 = chars.get(i + 1).copied();
                    let n2 = chars.get(i + 2).copied();
                    if n1 == Some('\\') {
                        // Escaped char literal: skip quote, backslash, escaped char.
                        code.push('\'');
                        st = St::CharLit;
                        i += 3;
                    } else if n2 == Some('\'') && n1 != Some('\'') {
                        // Plain char literal like 'x'.
                        code.push('\'');
                        code.push(' ');
                        code.push('\'');
                        i += 3;
                    } else {
                        // Lifetime: keep the tick, continue in code.
                        code.push('\'');
                        i += 1;
                    }
                } else {
                    code.push(c);
                    i += 1;
                }
            }
            St::LineComment => {
                comment.push(c);
                i += 1;
            }
            St::BlockComment(depth) => {
                let next = chars.get(i + 1).copied();
                if c == '*' && next == Some('/') {
                    st = if depth == 1 { St::Code } else { St::BlockComment(depth - 1) };
                    i += 2;
                } else if c == '/' && next == Some('*') {
                    st = St::BlockComment(depth + 1);
                    i += 2;
                } else {
                    comment.push(c);
                    i += 1;
                }
            }
            St::Str => {
                if c == '\\' {
                    // Skip escaped quote/backslash wholesale; other escapes
                    // advance one char and let the payload blank normally.
                    if matches!(chars.get(i + 1).copied(), Some('"' | '\\')) {
                        i += 2;
                    } else {
                        i += 1;
                    }
                } else if c == '"' {
                    code.push('"');
                    st = St::Code;
                    i += 1;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            St::RawStr(h) => {
                if c == '"' && (0..h as usize).all(|k| chars.get(i + 1 + k) == Some(&'#')) {
                    code.push('"');
                    st = St::Code;
                    i += 1 + h as usize;
                } else {
                    code.push(' ');
                    i += 1;
                }
            }
            St::CharLit => {
                if c == '\'' {
                    code.push('\'');
                    st = St::Code;
                }
                i += 1;
            }
        }
    }
    // Push the final (newline-less) line; a trailing newline already flushed it.
    if !text.ends_with('\n') && (!code.is_empty() || !comment.is_empty()) {
        lines.push(new_line(&code, &comment));
    }
    lines
}

/// If `chars[i]` starts a raw string (`r"` / `r#"` / …), return the hash count.
fn raw_str_hashes(chars: &[char], i: usize) -> Option<u32> {
    let mut j = i + 1;
    let mut hashes = 0u32;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some(hashes)
    } else {
        None
    }
}

fn new_line(code: &str, comment: &str) -> Line {
    Line {
        raw: String::new(), // filled by caller of SourceFile::parse via raw split below
        code: code.to_string(),
        comment: comment.to_string(),
        in_test: false,
        region_allows: Vec::new(),
        line_allows: Vec::new(),
    }
}

/// Brace-count `#[cfg(test)] mod … { … }` bodies and flag their lines.
fn mark_test_mods(lines: &mut [Line]) {
    let mut i = 0usize;
    while i < lines.len() {
        if lines[i].code.contains("#[cfg(test)]") {
            // Skip further attributes / comments / blank lines, then require
            // a `mod` item so `#[cfg(test)]` on fns does not start a region.
            let mut j = i + 1;
            while j < lines.len() {
                let ct = lines[j].code.trim();
                if ct.starts_with("#[") || (ct.is_empty() && !lines[j].comment.is_empty()) {
                    j += 1;
                } else {
                    break;
                }
            }
            let starts_mod = lines
                .get(j)
                .map(|l| {
                    let ct = l.code.trim();
                    ct.starts_with("mod ") || ct.starts_with("pub mod ") || ct == "mod"
                })
                .unwrap_or(false);
            if starts_mod {
                let mut depth = 0i64;
                let mut opened = false;
                let mut k = j;
                while k < lines.len() {
                    for ch in lines[k].code.chars() {
                        match ch {
                            '{' => {
                                depth += 1;
                                opened = true;
                            }
                            '}' => depth -= 1,
                            _ => {}
                        }
                    }
                    lines[k].in_test = true;
                    if opened && depth <= 0 {
                        break;
                    }
                    k += 1;
                }
                i = k + 1;
                continue;
            }
        }
        i += 1;
    }
}

/// Track `lint: allow(TAG)` / `lint: end-allow(TAG)` annotations.
fn mark_allow_regions(lines: &mut [Line]) {
    let mut active: Vec<String> = Vec::new();
    for line in lines.iter_mut() {
        let starts = parse_tags(&line.comment, "lint: allow(");
        let ends = parse_tags(&line.comment, "lint: end-allow(");
        let pure_comment = line.code.trim().is_empty();
        if pure_comment {
            for t in &starts {
                if !active.contains(t) {
                    active.push(t.clone());
                }
            }
        } else {
            line.line_allows = starts.clone();
        }
        line.region_allows = active.clone();
        for t in &ends {
            active.retain(|a| a != t);
        }
    }
}

/// Extract every `<marker>TAG)` tag from a comment string.
fn parse_tags(comment: &str, marker: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut rest = comment;
    while let Some(pos) = rest.find(marker) {
        let after = &rest[pos + marker.len()..];
        if let Some(close) = after.find(')') {
            out.push(after[..close].trim().to_string());
            rest = &after[close + 1..];
        } else {
            break;
        }
    }
    out
}

/// Attach raw line text (parse blanks it, findings want the original).
pub fn parse_with_raw(path: &str, text: &str) -> SourceFile {
    let mut file = SourceFile::parse(path, text);
    for (i, raw) in text.lines().enumerate() {
        if let Some(l) = file.lines.get_mut(i) {
            l.raw = raw.to_string();
        }
    }
    file
}

/// Word-boundary substring search: `word` must not be flanked by identifier
/// characters. Works on the `code` view so literals/comments never match.
pub fn has_word(hay: &str, word: &str) -> bool {
    if word.is_empty() {
        return false;
    }
    let bytes = hay.as_bytes();
    let mut start = 0usize;
    while let Some(pos) = hay[start..].find(word) {
        let p = start + pos;
        let before_ok = p == 0 || !is_ident(bytes[p - 1] as char);
        let end = p + word.len();
        let after_ok = end >= bytes.len() || !is_ident(bytes[end] as char);
        if before_ok && after_ok {
            return true;
        }
        start = p + word.len();
    }
    false
}

/// Leading identifier of a trimmed line (`DeadlineExceeded { .. },` → name).
pub fn leading_ident(s: &str) -> Option<String> {
    let t = s.trim_start();
    let ident: String = t.chars().take_while(|c| is_ident(*c)).collect();
    if ident.is_empty() || ident.chars().next().map(|c| c.is_ascii_digit()).unwrap_or(true) {
        None
    } else {
        Some(ident)
    }
}

/// Net `{`/`}` delta of a code-view line.
pub fn brace_delta(code: &str) -> i64 {
    let mut d = 0i64;
    for c in code.chars() {
        match c {
            '{' => d += 1,
            '}' => d -= 1,
            _ => {}
        }
    }
    d
}

/// Locate the body of `fn <name>` as an inclusive line-index range
/// (from the signature line through the closing brace).
pub fn fn_region(file: &SourceFile, name: &str) -> Option<(usize, usize)> {
    let needle = format!("fn {name}");
    let mut start = None;
    for (i, l) in file.lines.iter().enumerate() {
        if l.code.contains(&needle) && has_word(&l.code, name) {
            start = Some(i);
            break;
        }
    }
    let start = start?;
    let mut depth = 0i64;
    let mut opened = false;
    for (i, l) in file.lines.iter().enumerate().skip(start) {
        for c in l.code.chars() {
            match c {
                '{' => {
                    depth += 1;
                    opened = true;
                }
                '}' => depth -= 1,
                _ => {}
            }
        }
        if opened && depth <= 0 {
            return Some((start, i));
        }
    }
    Some((start, file.lines.len().saturating_sub(1)))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn comments_are_stripped_from_code_view() {
        let f = parse_with_raw("t.rs", "let x = 1; // vec! here\n");
        assert_eq!(f.lines[0].code.trim_end(), "let x = 1;");
        assert!(f.lines[0].comment.contains("vec! here"));
    }

    #[test]
    fn string_contents_are_blanked() {
        let f = parse_with_raw("t.rs", "let s = \"unsafe vec! { } \"; let y = 2;\n");
        assert!(!f.lines[0].code.contains("unsafe"));
        assert!(!f.lines[0].code.contains("vec!"));
        // Braces inside strings must not affect brace counting.
        assert_eq!(brace_delta(&f.lines[0].code), 0);
        assert!(f.lines[0].code.contains("let y = 2;"));
    }

    #[test]
    fn raw_strings_and_escapes_are_blanked() {
        let src = "let a = r#\"unsafe \"quoted\" {\"#; let b = \"\\\"unsafe\\\"\";\n";
        let f = parse_with_raw("t.rs", src);
        assert!(!f.lines[0].code.contains("unsafe"));
        assert_eq!(brace_delta(&f.lines[0].code), 0);
    }

    #[test]
    fn char_literals_and_lifetimes() {
        let src = "fn f<'a>(c: char) -> bool { c == '{' || c == '\\'' }\n";
        let f = parse_with_raw("t.rs", src);
        // The '{' char literal is blanked; only the fn-body braces count.
        assert_eq!(brace_delta(&f.lines[0].code), 0);
        assert!(f.lines[0].code.contains("fn f<'a>"));
    }

    #[test]
    fn block_comments_nest() {
        let src = "let x = 1; /* outer /* inner */ still comment */ let y = 2;\n";
        let f = parse_with_raw("t.rs", src);
        assert!(f.lines[0].code.contains("let x = 1;"));
        assert!(f.lines[0].code.contains("let y = 2;"));
        assert!(!f.lines[0].code.contains("still comment"));
    }

    #[test]
    fn cfg_test_mod_marks_lines() {
        let src = "fn live() {}\n#[cfg(test)]\nmod tests {\n    fn t() {}\n}\nfn after() {}\n";
        let f = parse_with_raw("t.rs", src);
        assert!(!f.lines[0].in_test);
        assert!(f.lines[2].in_test);
        assert!(f.lines[3].in_test);
        assert!(f.lines[4].in_test);
        assert!(!f.lines[5].in_test);
    }

    #[test]
    fn allow_regions_and_line_waivers() {
        let src = "// lint: allow(alloc)\nlet v = vec![1];\n// lint: end-allow(alloc)\nlet w = \
                   vec![2];\nlet x = vec![3]; // lint: allow(alloc)\n";
        let f = parse_with_raw("t.rs", src);
        assert!(f.lines[1].allowed("alloc"));
        assert!(!f.lines[3].allowed("alloc"));
        assert!(f.lines[4].allowed("alloc"));
    }

    #[test]
    fn word_boundaries() {
        assert!(has_word("use Ordering::Relaxed;", "Relaxed"));
        assert!(!has_word("deadline_drops", "drain"));
        assert!(!has_word("shutdown_flag", "shutdown"));
        assert!(has_word("self.shutdown.store", "shutdown"));
    }

    #[test]
    fn fn_region_spans_body() {
        let src = "fn a() {\n    let x = 1;\n}\nfn b() {}\n";
        let f = parse_with_raw("t.rs", src);
        assert_eq!(fn_region(&f, "a"), Some((0, 2)));
        assert_eq!(fn_region(&f, "b"), Some((3, 3)));
    }
}
