//! The seven invariant rules. Each takes pre-scanned sources and returns
//! `Finding`s with exact `file:line` anchors; the driver aggregates and
//! exits non-zero when any rule fires.

use crate::scan::{brace_delta, fn_region, has_word, leading_ident, SourceFile};

/// One diagnostic: `file:line: rule-id: message`.
#[derive(Debug, PartialEq, Eq)]
pub struct Finding {
    pub file: String,
    pub line: usize,
    pub rule: &'static str,
    pub message: String,
}

impl std::fmt::Display for Finding {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}:{}: {}: {}", self.file, self.line, self.rule, self.message)
    }
}

fn finding(file: &str, line: usize, rule: &'static str, message: String) -> Finding {
    Finding { file: file.to_string(), line, rule, message }
}

fn is_ident_byte(b: u8) -> bool {
    b.is_ascii_alphanumeric() || b == b'_'
}

// ---------------------------------------------------------------------------
// Rule 1: unsafe-safety — every `unsafe` is preceded by a `// SAFETY:` comment.
// ---------------------------------------------------------------------------

/// Flag `unsafe` tokens whose contiguous preceding comment/attribute block
/// (or trailing same-line comment) lacks a `SAFETY:` marker. Attributes may
/// interleave with the comment in either order; a blank line breaks the block.
pub fn rule_unsafe_safety(file: &SourceFile) -> Vec<Finding> {
    let mut out = Vec::new();
    for (idx, line) in file.lines.iter().enumerate() {
        if !has_word(&line.code, "unsafe") {
            continue;
        }
        if line.comment.contains("SAFETY:") {
            continue;
        }
        let mut ok = false;
        let mut j = idx;
        while j > 0 {
            j -= 1;
            let l = &file.lines[j];
            let ct = l.code.trim();
            let is_attr = ct.starts_with("#[") || ct.starts_with("#!");
            let pure_comment = ct.is_empty() && !l.comment.is_empty();
            if l.comment.contains("SAFETY:") && (pure_comment || is_attr) {
                ok = true;
                break;
            }
            if pure_comment || is_attr {
                continue;
            }
            break; // code or a blank line ends the contiguous block
        }
        if !ok {
            out.push(finding(
                &file.path,
                file.lineno(idx),
                "unsafe-safety",
                "`unsafe` without an immediately preceding `// SAFETY:` comment".to_string(),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 2: taxonomy-sync — ServeError variants/statuses agree four ways.
// ---------------------------------------------------------------------------

/// Extract `(variant, line)` pairs from `enum <name> { … }`.
fn enum_variants(file: &SourceFile, name: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let mut depth = 0i64;
    let mut in_enum = false;
    for (i, l) in file.lines.iter().enumerate() {
        if !in_enum {
            if has_word(&l.code, "enum") && has_word(&l.code, name) {
                in_enum = true;
                depth = brace_delta(&l.code);
            }
            continue;
        }
        if depth == 1 {
            if let Some(v) = leading_ident(&l.code) {
                if v.chars().next().map(|c| c.is_ascii_uppercase()).unwrap_or(false) {
                    out.push((v, file.lineno(i)));
                }
            }
        }
        depth += brace_delta(&l.code);
        if depth <= 0 {
            break;
        }
    }
    out
}

/// Parse `| `Variant …` | 504 | …` markdown rows (first cell backticked
/// identifier, second cell a bare status number).
fn table_row(row: &str) -> Option<(String, u16)> {
    let cells: Vec<&str> = row.split('|').collect();
    if cells.len() < 3 {
        return None;
    }
    let first = cells[1].trim();
    let status: u16 = cells[2].trim().parse().ok()?;
    let tick = first.find('`')?;
    let after = &first[tick + 1..];
    let ident: String = after
        .chars()
        .take_while(|c| c.is_ascii_alphanumeric() || *c == '_')
        .collect();
    if ident.is_empty() {
        None
    } else {
        Some((ident, status))
    }
}

/// Rows of the `//! | … |` module-doc table in the router file.
fn router_doc_rows(router: &SourceFile) -> Vec<(String, u16, usize)> {
    let mut out = Vec::new();
    for (i, l) in router.lines.iter().enumerate() {
        let t = l.raw.trim_start();
        if let Some(rest) = t.strip_prefix("//!") {
            let rest = rest.trim_start();
            if rest.starts_with('|') {
                if let Some((v, s)) = table_row(rest) {
                    out.push((v, s, router.lineno(i)));
                }
            }
        }
    }
    out
}

/// `ServeError::X { .. } => (504, "X")` arms inside `fn serve_error_parts`.
fn status_match_arms(router: &SourceFile) -> Vec<(String, u16, String, usize)> {
    let mut out = Vec::new();
    let Some((lo, hi)) = fn_region(router, "serve_error_parts") else {
        return out;
    };
    for i in lo..=hi {
        let raw = &router.lines[i].raw;
        let Some(pos) = raw.find("ServeError::") else { continue };
        let after = &raw[pos + "ServeError::".len()..];
        let variant: String =
            after.chars().take_while(|c| c.is_ascii_alphanumeric() || *c == '_').collect();
        if variant.is_empty() || !raw.contains("=>") {
            continue;
        }
        let arrow = raw.find("=>").unwrap_or(0);
        let tail = &raw[arrow..];
        let digits: String = tail
            .chars()
            .skip_while(|c| !c.is_ascii_digit())
            .take_while(|c| c.is_ascii_digit())
            .collect();
        let Ok(status) = digits.parse::<u16>() else { continue };
        let code = match (tail.find('"'), tail.rfind('"')) {
            (Some(a), Some(b)) if b > a => tail[a + 1..b].to_string(),
            _ => String::new(),
        };
        out.push((variant, status, code, router.lineno(i)));
    }
    out
}

/// Rows of the README taxonomy table, scoped to its section heading.
fn readme_taxonomy_rows(readme: &str) -> Vec<(String, u16, usize)> {
    let mut out = Vec::new();
    let mut in_section = false;
    for (i, line) in readme.lines().enumerate() {
        if line.starts_with("## ") {
            in_section = line.contains("Serving error taxonomy");
            continue;
        }
        if in_section && line.trim_start().starts_with('|') {
            if let Some((v, s)) = table_row(line) {
                out.push((v, s, i + 1));
            }
        }
    }
    out
}

/// Diff the four representations of the `ServeError` taxonomy.
pub fn rule_taxonomy(
    enum_file: &SourceFile,
    router_file: &SourceFile,
    readme_path: &str,
    readme: &str,
) -> Vec<Finding> {
    const RULE: &str = "taxonomy-sync";
    let mut out = Vec::new();
    let variants = enum_variants(enum_file, "ServeError");
    if variants.is_empty() {
        out.push(finding(&enum_file.path, 1, RULE, "could not locate `enum ServeError`".into()));
        return out;
    }
    let arms = status_match_arms(router_file);
    let doc = router_doc_rows(router_file);
    let md = readme_taxonomy_rows(readme);
    let arm_line = arms.first().map(|a| a.3).unwrap_or(1);

    // Every enum variant must appear in all three derived tables.
    for (v, line) in &variants {
        if !arms.iter().any(|(a, _, _, _)| a == v) {
            out.push(finding(
                &router_file.path,
                arm_line,
                RULE,
                format!(
                    "variant `{v}` (enum at {}:{line}) missing from `serve_error_parts`",
                    enum_file.path
                ),
            ));
        }
        if !doc.iter().any(|(a, _, _)| a == v) {
            out.push(finding(
                &router_file.path,
                1,
                RULE,
                format!("variant `{v}` missing from the router module-doc table"),
            ));
        }
        if !md.iter().any(|(a, _, _)| a == v) {
            out.push(finding(
                readme_path,
                1,
                RULE,
                format!("variant `{v}` missing from the README taxonomy table"),
            ));
        }
    }
    // No stale rows anywhere.
    for (a, _, _, line) in &arms {
        if !variants.iter().any(|(v, _)| v == a) {
            out.push(finding(
                &router_file.path,
                *line,
                RULE,
                format!("`serve_error_parts` arm `{a}` has no matching enum variant"),
            ));
        }
    }
    for (a, _, line) in &doc {
        if !variants.iter().any(|(v, _)| v == a) {
            out.push(finding(
                &router_file.path,
                *line,
                RULE,
                format!("module-doc table row `{a}` has no matching enum variant"),
            ));
        }
    }
    for (a, _, line) in &md {
        if !variants.iter().any(|(v, _)| v == a) {
            out.push(finding(
                readme_path,
                *line,
                RULE,
                format!("README taxonomy row `{a}` has no matching enum variant"),
            ));
        }
    }
    // Statuses and wire code strings must agree with the match arms.
    for (a, status, code, line) in &arms {
        if code != a {
            out.push(finding(
                &router_file.path,
                *line,
                RULE,
                format!("wire code string \"{code}\" does not equal variant name `{a}`"),
            ));
        }
        if let Some((_, doc_status, doc_line)) = doc.iter().find(|(v, _, _)| v == a) {
            if doc_status != status {
                out.push(finding(
                    &router_file.path,
                    *doc_line,
                    RULE,
                    format!(
                        "module-doc table says `{a}` → {doc_status}, match arm at line {line} \
                         says {status}"
                    ),
                ));
            }
        }
        if let Some((_, md_status, md_line)) = md.iter().find(|(v, _, _)| v == a) {
            if md_status != status {
                out.push(finding(
                    readme_path,
                    *md_line,
                    RULE,
                    format!(
                        "README taxonomy says `{a}` → {md_status}, match arm at line {line} \
                         says {status}"
                    ),
                ));
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 3: bench-rows — frozen BENCH_hotpath.json rows stay in the sources.
// ---------------------------------------------------------------------------

/// Every manifest row name must appear verbatim (as a string literal) in at
/// least one bench source. `bench_sources` is `(path, raw text)`.
pub fn rule_bench_rows(
    manifest_path: &str,
    manifest: &str,
    bench_sources: &[(String, String)],
) -> Vec<Finding> {
    const RULE: &str = "bench-rows";
    let mut out = Vec::new();
    let mut rows = 0usize;
    for (i, line) in manifest.lines().enumerate() {
        let row = line.trim();
        if row.is_empty() || row.starts_with('#') {
            continue;
        }
        rows += 1;
        let needle = format!("\"{row}\"");
        if !bench_sources.iter().any(|(_, text)| text.contains(&needle)) {
            out.push(finding(
                manifest_path,
                i + 1,
                RULE,
                format!("frozen bench row \"{row}\" not found in any bench source"),
            ));
        }
    }
    if rows == 0 {
        out.push(finding(manifest_path, 1, RULE, "frozen-row manifest is empty".into()));
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 4: metrics-surface — counters flow into Snapshot, JSON, and summary.
// ---------------------------------------------------------------------------

/// `(field, line)` pairs of `name: <type>` fields inside `struct <name>`,
/// filtered by a substring the field's type must contain.
fn struct_fields(file: &SourceFile, name: &str, type_filter: &str) -> Vec<(String, usize)> {
    let mut out = Vec::new();
    let mut depth = 0i64;
    let mut in_struct = false;
    for (i, l) in file.lines.iter().enumerate() {
        if !in_struct {
            if has_word(&l.code, "struct") && has_word(&l.code, name) {
                in_struct = true;
                depth = brace_delta(&l.code);
            }
            continue;
        }
        if depth == 1 && l.code.contains(type_filter) && l.code.contains(':') {
            let t = l.code.trim();
            let t = t.strip_prefix("pub ").unwrap_or(t);
            if let Some(f) = leading_ident(t) {
                out.push((f, file.lineno(i)));
            }
        }
        depth += brace_delta(&l.code);
        if depth <= 0 {
            break;
        }
    }
    out
}

fn region_text(file: &SourceFile, region: Option<(usize, usize)>, raw: bool) -> String {
    let Some((lo, hi)) = region else { return String::new() };
    let mut s = String::new();
    for l in &file.lines[lo..=hi] {
        s.push_str(if raw { &l.raw } else { &l.code });
        s.push('\n');
    }
    s
}

/// Every `Metrics` AtomicU64 counter must be read in `fn snapshot`; every
/// `Snapshot` field must be emitted as a JSON key in `fn to_json` and
/// referenced by `print_serve_summary` in main.rs.
pub fn rule_metrics_surface(metrics: &SourceFile, main: &SourceFile) -> Vec<Finding> {
    const RULE: &str = "metrics-surface";
    let mut out = Vec::new();
    let counters = struct_fields(metrics, "Metrics", "AtomicU64");
    if counters.is_empty() {
        out.push(finding(
            &metrics.path,
            1,
            RULE,
            "no AtomicU64 counters found in `struct Metrics`".into(),
        ));
    }
    let snapshot_body = region_text(metrics, fn_region(metrics, "snapshot"), false);
    for (c, line) in &counters {
        if !has_word(&snapshot_body, c) {
            out.push(finding(
                &metrics.path,
                *line,
                RULE,
                format!("counter `{c}` is not read in `fn snapshot`"),
            ));
        }
    }
    let fields = struct_fields(metrics, "Snapshot", ":");
    if fields.is_empty() {
        out.push(finding(&metrics.path, 1, RULE, "no fields found in `struct Snapshot`".into()));
    }
    let json_body = region_text(metrics, fn_region(metrics, "to_json"), true);
    let summary_body = region_text(main, fn_region(main, "print_serve_summary"), false);
    for (f, line) in &fields {
        if !json_body.contains(&format!("\"{f}\"")) {
            out.push(finding(
                &metrics.path,
                *line,
                RULE,
                format!("Snapshot field `{f}` is not emitted as a key in `fn to_json`"),
            ));
        }
        if !has_word(&summary_body, f) {
            out.push(finding(
                &metrics.path,
                *line,
                RULE,
                format!(
                    "Snapshot field `{f}` does not surface in `print_serve_summary` ({})",
                    main.path
                ),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 5: config-docs — every parsed config key is documented in the README.
// ---------------------------------------------------------------------------

/// Keys are any `get("…")` argument in non-test config code; each must appear
/// (word-bounded) somewhere in the README.
pub fn rule_config_docs(config: &SourceFile, readme_path: &str, readme: &str) -> Vec<Finding> {
    const RULE: &str = "config-docs";
    let mut keys: Vec<(String, usize)> = Vec::new();
    for (i, l) in config.lines.iter().enumerate() {
        if l.in_test {
            continue;
        }
        let mut rest = l.raw.as_str();
        while let Some(pos) = rest.find("get(\"") {
            let after = &rest[pos + 5..];
            let Some(end) = after.find('"') else { break };
            let key = &after[..end];
            if !key.is_empty() && !keys.iter().any(|(k, _)| k == key) {
                keys.push((key.to_string(), config.lineno(i)));
            }
            rest = &after[end..];
        }
    }
    let mut out = Vec::new();
    if keys.is_empty() {
        out.push(finding(&config.path, 1, RULE, "no `get(\"…\")` config keys found".into()));
    }
    for (k, line) in &keys {
        if !has_word(readme, k) {
            out.push(finding(
                &config.path,
                *line,
                RULE,
                format!("config key `{k}` is parsed here but not documented in {readme_path}"),
            ));
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 6: hotpath-alloc — allocation-prone constructs on hot-path modules.
// ---------------------------------------------------------------------------

/// Allocation-prone constructs forbidden on hot-path modules.
pub const ALLOC_CONSTRUCTS: [&str; 5] = ["vec!", "Vec::new", "format!", "to_string", "Box::new"];

/// Boundary-aware construct search on the code view.
fn has_construct(code: &str, pat: &str) -> bool {
    let bytes = code.as_bytes();
    let pat_ends_ident = pat.as_bytes().last().map(|b| is_ident_byte(*b)).unwrap_or(false);
    let mut start = 0usize;
    while let Some(pos) = code[start..].find(pat) {
        let p = start + pos;
        let before_ok = p == 0 || !is_ident_byte(bytes[p - 1]);
        let end = p + pat.len();
        let after_ok = !pat_ends_ident || end >= bytes.len() || !is_ident_byte(bytes[end]);
        if before_ok && after_ok {
            return true;
        }
        start = end;
    }
    false
}

/// Flag alloc-prone constructs outside tests and `lint: allow(alloc)` regions.
pub fn rule_hotpath_alloc(file: &SourceFile) -> Vec<Finding> {
    const RULE: &str = "hotpath-alloc";
    let mut out = Vec::new();
    for (i, l) in file.lines.iter().enumerate() {
        if l.in_test || l.allowed("alloc") {
            continue;
        }
        for pat in ALLOC_CONSTRUCTS {
            if has_construct(&l.code, pat) {
                out.push(finding(
                    &file.path,
                    file.lineno(i),
                    RULE,
                    format!(
                        "`{pat}` on a hot-path module (wrap in `// lint: allow(alloc)` if cold)"
                    ),
                ));
                break;
            }
        }
    }
    out
}

// ---------------------------------------------------------------------------
// Rule 7: flag-ordering — no Relaxed on cross-thread control flags.
// ---------------------------------------------------------------------------

/// Atom names that act as cross-thread control flags: a `Relaxed` load/store
/// on a line naming one of these is almost always an ordering bug.
pub const FLAG_ALLOWLIST: [&str; 4] = ["shutdown", "drain", "draining", "generation"];

/// Flag `Ordering::Relaxed` (or a bare `Relaxed` token) on lines that also
/// name a cross-thread control flag. `// lint: allow(relaxed-flag)` waives.
pub fn rule_flag_ordering(file: &SourceFile, flags: &[&str]) -> Vec<Finding> {
    const RULE: &str = "flag-ordering";
    let mut out = Vec::new();
    for (i, l) in file.lines.iter().enumerate() {
        if l.in_test || l.allowed("relaxed-flag") {
            continue;
        }
        if !has_word(&l.code, "Relaxed") {
            continue;
        }
        for flag in flags {
            if has_word(&l.code, flag) {
                out.push(finding(
                    &file.path,
                    file.lineno(i),
                    RULE,
                    format!(
                        "`Ordering::Relaxed` on cross-thread flag `{flag}` — use Acquire/Release"
                    ),
                ));
                break;
            }
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::scan::parse_with_raw;

    fn one(mut findings: Vec<Finding>) -> Finding {
        assert_eq!(findings.len(), 1, "expected exactly one finding, got {findings:?}");
        findings.pop().unwrap()
    }

    // --- rule 1 -----------------------------------------------------------

    #[test]
    fn unsafe_safety_passes_with_comment() {
        let src = "\
// SAFETY: len-bounded unaligned loads; AVX2 checked by the dispatcher.
#[target_feature(enable = \"avx2\")]
unsafe fn kernel(a: &[f32]) {}

fn call() {
    // SAFETY: kernel only reads a.len() floats.
    unsafe { kernel(&[]) };
    let ok = \"the word unsafe in a string is fine\";
}
";
        let f = parse_with_raw("fix.rs", src);
        assert!(rule_unsafe_safety(&f).is_empty());
    }

    #[test]
    fn unsafe_safety_flags_missing_comment() {
        let src = "\
fn call() {
    let x = 1;
    unsafe { core::hint::unreachable_unchecked() };
}
";
        let f = parse_with_raw("fix.rs", src);
        let fnd = one(rule_unsafe_safety(&f));
        assert_eq!((fnd.file.as_str(), fnd.line, fnd.rule), ("fix.rs", 3, "unsafe-safety"));
    }

    #[test]
    fn unsafe_safety_blank_line_breaks_block() {
        let src = "// SAFETY: too far away.\n\nunsafe fn f() {}\n";
        let f = parse_with_raw("fix.rs", src);
        assert_eq!(one(rule_unsafe_safety(&f)).line, 3);
    }

    #[test]
    fn unsafe_safety_accepts_comment_above_attribute() {
        let src = "// SAFETY: fine.\n#[inline]\nunsafe fn f() {}\n";
        let f = parse_with_raw("fix.rs", src);
        assert!(rule_unsafe_safety(&f).is_empty());
    }

    // --- rule 2 -----------------------------------------------------------

    fn taxonomy_enum(src: &str) -> SourceFile {
        parse_with_raw("coordinator.rs", src)
    }

    const ENUM_OK: &str = "\
pub enum ServeError {
    /// Budget lapsed.
    DeadlineExceeded { waited_us: u64 },
    QueueFull { depth: usize },
}
";

    const ROUTER_OK: &str = "\
//! | `ServeError` variant | status |
//! |----------------------|--------|
//! | `DeadlineExceeded`   | 504    |
//! | `QueueFull`          | 503    |

pub fn serve_error_parts(e: &ServeError) -> (u16, &'static str) {
    match e {
        ServeError::DeadlineExceeded { .. } => (504, \"DeadlineExceeded\"),
        ServeError::QueueFull { .. } => (503, \"QueueFull\"),
    }
}
";

    const README_OK: &str = "\
## Serving error taxonomy

| variant | http status | when |
|---------|-------------|------|
| `DeadlineExceeded { waited_us }` | 504 | budget lapsed |
| `QueueFull { depth }` | 503 | queue full |

## Next section
";

    #[test]
    fn taxonomy_passes_when_synced() {
        let e = taxonomy_enum(ENUM_OK);
        let r = parse_with_raw("router.rs", ROUTER_OK);
        assert!(rule_taxonomy(&e, &r, "README.md", README_OK).is_empty());
    }

    #[test]
    fn taxonomy_flags_status_drift() {
        let e = taxonomy_enum(ENUM_OK);
        let drifted = ROUTER_OK.replace(
            "| `QueueFull`          | 503    |",
            "| `QueueFull`          | 500    |",
        );
        let r = parse_with_raw("router.rs", &drifted);
        let fnd = one(rule_taxonomy(&e, &r, "README.md", README_OK));
        // The drifted module-doc row is line 4 of the router fixture.
        assert_eq!((fnd.file.as_str(), fnd.line, fnd.rule), ("router.rs", 4, "taxonomy-sync"));
        assert!(fnd.message.contains("500"));
    }

    #[test]
    fn taxonomy_flags_missing_readme_row() {
        let e = taxonomy_enum(ENUM_OK);
        let r = parse_with_raw("router.rs", ROUTER_OK);
        let md = README_OK.replace("| `QueueFull { depth }` | 503 | queue full |\n", "");
        let findings = rule_taxonomy(&e, &r, "README.md", &md);
        let fnd = one(findings);
        assert_eq!((fnd.file.as_str(), fnd.rule), ("README.md", "taxonomy-sync"));
        assert!(fnd.message.contains("`QueueFull`"));
    }

    #[test]
    fn taxonomy_flags_stale_arm() {
        let e = taxonomy_enum(
            "pub enum ServeError {\n    DeadlineExceeded { waited_us: u64 },\n    QueueFull { \
             depth: usize },\n    Draining,\n}\n",
        );
        let r = parse_with_raw("router.rs", ROUTER_OK);
        let findings = rule_taxonomy(&e, &r, "README.md", README_OK);
        // `Draining` missing from all three derived tables.
        assert_eq!(findings.len(), 3);
        assert!(findings.iter().all(|f| f.message.contains("`Draining`")));
    }

    // --- rule 3 -----------------------------------------------------------

    #[test]
    fn bench_rows_pass_when_present() {
        let bench = (
            "b.rs".to_string(),
            "suite.bench(\"im2col+GEMM, per image\", || {})".to_string(),
        );
        let manifest = "# frozen\nim2col+GEMM, per image\n";
        assert!(rule_bench_rows("m.txt", manifest, &[bench]).is_empty());
    }

    #[test]
    fn bench_rows_flag_missing_row() {
        let bench = ("b.rs".to_string(), "suite.bench(\"other row\", || {})".to_string());
        let manifest = "# frozen\nim2col+GEMM, per image\n";
        let fnd = one(rule_bench_rows("m.txt", manifest, &[bench]));
        assert_eq!((fnd.file.as_str(), fnd.line, fnd.rule), ("m.txt", 2, "bench-rows"));
    }

    // --- rule 4 -----------------------------------------------------------

    const METRICS_OK: &str = "\
pub struct Metrics {
    pub requests_enqueued: AtomicU64,
}

pub struct Snapshot {
    pub enqueued: u64,
}

impl Metrics {
    pub fn snapshot(&self) -> Snapshot {
        Snapshot { enqueued: self.requests_enqueued.load(Ordering::Relaxed) }
    }
}

impl Snapshot {
    pub fn to_json(&self) -> Vec<(&'static str, u64)> {
        vec![(\"enqueued\", self.enqueued)]
    }
}
";

    #[test]
    fn metrics_surface_passes_when_plumbed() {
        let m = parse_with_raw("metrics.rs", METRICS_OK);
        let main = parse_with_raw(
            "main.rs",
            "fn print_serve_summary(s: &Snapshot) {\n    println!(\"{}\", s.enqueued);\n}\n",
        );
        assert!(rule_metrics_surface(&m, &main).is_empty());
    }

    #[test]
    fn metrics_surface_flags_summary_gap() {
        let m = parse_with_raw("metrics.rs", METRICS_OK);
        let main = parse_with_raw("main.rs", "fn print_serve_summary(_s: &Snapshot) {}\n");
        let fnd = one(rule_metrics_surface(&m, &main));
        // `enqueued` is declared on line 6 of the metrics fixture.
        assert_eq!((fnd.file.as_str(), fnd.line, fnd.rule), ("metrics.rs", 6, "metrics-surface"));
        assert!(fnd.message.contains("print_serve_summary"));
    }

    #[test]
    fn metrics_surface_flags_unread_counter() {
        let src = METRICS_OK.replace(
            "Snapshot { enqueued: self.requests_enqueued.load(Ordering::Relaxed) }",
            "Snapshot { enqueued: 0 }",
        );
        let m = parse_with_raw("metrics.rs", &src);
        let main = parse_with_raw(
            "main.rs",
            "fn print_serve_summary(s: &Snapshot) {\n    println!(\"{}\", s.enqueued);\n}\n",
        );
        let fnd = one(rule_metrics_surface(&m, &main));
        assert_eq!((fnd.line, fnd.rule), (2, "metrics-surface"));
        assert!(fnd.message.contains("requests_enqueued"));
    }

    // --- rule 5 -----------------------------------------------------------

    #[test]
    fn config_docs_pass_when_documented() {
        let c = parse_with_raw("config.rs", "let r = doc.get(\"rows\");\n");
        let readme = "The `rows` key sets the array height.";
        assert!(rule_config_docs(&c, "README.md", readme).is_empty());
    }

    #[test]
    fn config_docs_flag_undocumented_key() {
        let c = parse_with_raw(
            "config.rs",
            "let r = doc.get(\"rows\");\nlet c = doc.get(\"cols\");\n",
        );
        let fnd = one(rule_config_docs(&c, "README.md", "Only `rows` is documented."));
        assert_eq!((fnd.file.as_str(), fnd.line, fnd.rule), ("config.rs", 2, "config-docs"));
        assert!(fnd.message.contains("`cols`"));
    }

    #[test]
    fn config_docs_skip_test_keys() {
        let src = "let r = doc.get(\"rows\");\n#[cfg(test)]\nmod tests {\n    fn t() { \
                   doc.get(\"only_in_tests\"); }\n}\n";
        let c = parse_with_raw("config.rs", src);
        assert!(rule_config_docs(&c, "README.md", "`rows` documented.").is_empty());
    }

    // --- rule 6 -----------------------------------------------------------

    #[test]
    fn hotpath_alloc_passes_with_annotations() {
        let src = "\
fn hot(out: &mut [f32]) {
    out[0] = 1.0;
}

// lint: allow(alloc) — builder path, runs once at startup.
fn cold() -> Vec<f32> {
    vec![0.0; 8]
}
// lint: end-allow(alloc)

fn label() -> String {
    format!(\"t{}\", 1) // lint: allow(alloc)
}

#[cfg(test)]
mod tests {
    fn t() -> Vec<u8> {
        vec![1, 2, 3]
    }
}
";
        let f = parse_with_raw("hot.rs", src);
        assert!(rule_hotpath_alloc(&f).is_empty());
    }

    #[test]
    fn hotpath_alloc_flags_bare_construct() {
        let src = "fn hot() {\n    let v = vec![0u8; 64];\n}\n";
        let f = parse_with_raw("hot.rs", src);
        let fnd = one(rule_hotpath_alloc(&f));
        assert_eq!((fnd.file.as_str(), fnd.line, fnd.rule), ("hot.rs", 2, "hotpath-alloc"));
        assert!(fnd.message.contains("vec!"));
    }

    #[test]
    fn hotpath_alloc_ignores_lookalikes() {
        // `to_vec`, `my_format!`-style idents, and strings must not fire.
        let src = "fn hot() {\n    let s = \"vec! format! Box::new\";\n    let n = \
                   slice.to_vec_len();\n}\n";
        let f = parse_with_raw("hot.rs", src);
        assert!(rule_hotpath_alloc(&f).is_empty());
    }

    // --- rule 7 -----------------------------------------------------------

    #[test]
    fn flag_ordering_passes_on_acquire_release() {
        let src = "\
fn drain(&self) {
    self.shutdown.store(true, Ordering::Release);
    while !self.shutdown.load(Ordering::Acquire) {}
    self.requests_completed.fetch_add(1, Ordering::Relaxed);
}
";
        let f = parse_with_raw("coord.rs", src);
        assert!(rule_flag_ordering(&f, &FLAG_ALLOWLIST).is_empty());
    }

    #[test]
    fn flag_ordering_flags_relaxed_flag() {
        let src = "fn stop(&self) {\n    self.shutdown.store(true, Ordering::Relaxed);\n}\n";
        let f = parse_with_raw("coord.rs", src);
        let fnd = one(rule_flag_ordering(&f, &FLAG_ALLOWLIST));
        assert_eq!((fnd.file.as_str(), fnd.line, fnd.rule), ("coord.rs", 2, "flag-ordering"));
        assert!(fnd.message.contains("shutdown"));
    }

    #[test]
    fn flag_ordering_ignores_substrings() {
        // `deadline_drops` contains "dr" but not the word "drain".
        let src = "fn f(&self) {\n    self.deadline_drops.fetch_add(1, Ordering::Relaxed);\n}\n";
        let f = parse_with_raw("coord.rs", src);
        assert!(rule_flag_ordering(&f, &FLAG_ALLOWLIST).is_empty());
    }
}
