//! Regenerate the paper's Table 2 and Table 3 (ours vs published values).
//!
//! ```sh
//! cargo run --release --example paper_tables [-- --artifacts artifacts]
//! ```
//!
//! Accuracy columns fill in once `make train` has produced
//! `artifacts/accuracy.json` (LeNet full-size; CIFAR rows are reduced-width
//! proxies on synthetic data — see DESIGN.md §5).

use tpu_imac::arch;
use tpu_imac::report::{self, AccuracyTable};
use tpu_imac::systolic::{ArrayConfig, SramConfig};

fn main() -> anyhow::Result<()> {
    let artifacts =
        std::env::args().skip_while(|a| a != "--artifacts").nth(1).unwrap_or_else(|| "artifacts".into());
    let evals = arch::evaluate_suite(&ArrayConfig::default(), &SramConfig::default())?;
    let acc = AccuracyTable::load(&format!("{artifacts}/accuracy.json"));
    println!("{}", report::table2(&evals, &acc).to_ascii());
    println!("{}", report::table3(&evals, &acc).to_ascii());
    Ok(())
}
