//! END-TO-END driver: the full three-layer stack on a real small workload.
//!
//! 1. Loads the two-step-trained LeNet (conv FP32 + ternary FC) produced by
//!    `make train`;
//! 2. Starts the rust serving coordinator with the **PJRT backend** (the
//!    JAX-AOT `lenet_conv_b{B}.hlo.txt` artifact compiled via the xla
//!    crate) and the **rust IMAC analog fabric** for the FC section —
//!    exactly the hardware partition (systolic conv → sign bridge →
//!    analog FC), with Python nowhere on the path;
//! 3. Replays a synthetic-MNIST test set as a batched request stream;
//! 4. Reports end-to-end accuracy (must match training-time ternary
//!    accuracy) and latency/throughput.
//!
//! ```sh
//! make artifacts && cargo run --release --example serve_mnist
//! ```

use anyhow::{Context, Result};

use tpu_imac::coordinator::{
    Coordinator, CoordinatorConfig, InferenceBackend, NativeBackend, PjrtConvBackend,
};
use tpu_imac::imac::{AdcConfig, ImacConfig};
use tpu_imac::nn::{DeployedModel, Tensor};
use tpu_imac::runtime::Runtime;

/// Deterministic synthetic-MNIST mirror of python/compile/datasets.py.
/// (Rust replays the *saved* test set if present; else it generates its own
/// images purely for throughput measurement.)
fn load_test_set(artifacts: &str) -> Option<(Vec<Tensor>, Vec<usize>)> {
    let path = format!("{artifacts}/testset_mnist.json");
    let text = std::fs::read_to_string(path).ok()?;
    let doc = tpu_imac::util::json::Json::parse(&text).ok()?;
    let images = doc.get("images").as_arr()?.to_vec();
    let labels: Vec<usize> =
        doc.get("labels").as_arr()?.iter().filter_map(|v| v.as_usize()).collect();
    let mut tensors = Vec::with_capacity(images.len());
    for img in &images {
        let data = img.as_f32_vec()?;
        tensors.push(Tensor::from_vec(28, 28, 1, data));
    }
    Some((tensors, labels))
}

fn main() -> Result<()> {
    let artifacts = std::env::args()
        .skip_while(|a| a != "--artifacts")
        .nth(1)
        .unwrap_or_else(|| "artifacts".into());
    let max_batch = 8usize;

    let model = DeployedModel::load(
        &format!("{artifacts}/weights_lenet.json"),
        &ImacConfig::default(),
        AdcConfig { bits: 0, full_scale: 1.0 },
        0,
    )
    .context("run `make train` first (produces artifacts/weights_lenet.json)")?;
    println!(
        "loaded {} [{}]: training-time fp32 {:.2}%, ternary {:.2}%",
        model.row,
        model.dataset,
        model.acc_fp32 * 100.0,
        model.acc_ternary * 100.0
    );
    drop(model);

    let artifacts2 = artifacts.clone();
    let coord = Coordinator::start(
        CoordinatorConfig { max_batch, ..Default::default() },
        move || -> Box<dyn InferenceBackend> {
            let model = DeployedModel::load(
                &format!("{artifacts2}/weights_lenet.json"),
                &ImacConfig::default(),
                AdcConfig { bits: 0, full_scale: 1.0 },
                0,
            )
            .expect("weights json");
            let artifact = format!("lenet_conv_b{max_batch}.hlo.txt");
            match Runtime::open(&artifacts2)
                .and_then(|mut rt| {
                    rt.check_spec(&ImacConfig::default())?;
                    rt.load(&artifact)?;
                    Ok(rt)
                })
                .and_then(|rt| PjrtConvBackend::new(rt, &artifact, model))
            {
                Ok(b) => {
                    eprintln!("backend: PJRT conv ({artifact}) + rust IMAC fabric");
                    Box::new(b)
                }
                Err(e) => {
                    eprintln!("PJRT unavailable ({e:#}); native fallback");
                    let m = DeployedModel::load(
                        &format!("{artifacts2}/weights_lenet.json"),
                        &ImacConfig::default(),
                        AdcConfig { bits: 0, full_scale: 1.0 },
                        0,
                    )
                    .expect("weights json");
                    Box::new(NativeBackend::new(m))
                }
            }
        },
    );
    let client = coord.client();

    // Request stream: the saved test set (accuracy + perf) or synthetic
    // noise (perf only).
    let (images, labels) = match load_test_set(&artifacts) {
        Some((i, l)) => {
            println!("replaying saved test set: {} images", i.len());
            (i, l)
        }
        None => {
            println!("no saved test set (artifacts/testset_mnist.json); using 512 noise images");
            let mut rng = tpu_imac::util::rng::Xoshiro256::seed_from_u64(3);
            let imgs = (0..512)
                .map(|_| Tensor::from_vec(28, 28, 1, (0..784).map(|_| rng.next_f32()).collect()))
                .collect();
            (imgs, Vec::new())
        }
    };

    let t0 = std::time::Instant::now();
    let mut rxs = Vec::with_capacity(images.len());
    for img in images {
        rxs.push(client.submit(img)?.1);
    }
    let mut correct = 0usize;
    let mut total = 0usize;
    for (i, rx) in rxs.into_iter().enumerate() {
        let resp = rx.recv()?;
        if let Some(&label) = labels.get(i) {
            total += 1;
            if resp.predicted == label {
                correct += 1;
            }
        }
    }
    let wall = t0.elapsed();
    let snap = coord.metrics.snapshot();

    println!(
        "\nserved {} requests in {:.3}s => {:.1} req/s",
        snap.completed,
        wall.as_secs_f64(),
        snap.completed as f64 / wall.as_secs_f64()
    );
    println!(
        "latency mean {:.2} ms | p50 {:.2} | p95 {:.2} | p99 {:.2} ms; {} batches (fill {:.0}%)",
        snap.mean_latency_us / 1e3,
        snap.p50_latency_us / 1e3,
        snap.p95_latency_us / 1e3,
        snap.p99_latency_us / 1e3,
        snap.batches,
        snap.mean_batch_fill * 100.0
    );
    println!(
        "stage totals: conv(PJRT) {:.1} ms, IMAC-FC {:.1} ms, queue {:.1} ms",
        snap.conv_us_total as f64 / 1e3,
        snap.imac_us_total as f64 / 1e3,
        snap.queue_us_total as f64 / 1e3
    );
    if total > 0 {
        println!(
            "end-to-end accuracy: {}/{} = {:.2}%",
            correct,
            total,
            100.0 * correct as f64 / total as f64
        );
    }
    coord.shutdown();
    Ok(())
}
