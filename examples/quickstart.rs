//! Quickstart: evaluate one CNN on the TPU-IMAC architecture model and run
//! one inference through the IMAC analog fabric.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use tpu_imac::arch;
use tpu_imac::imac::{AdcConfig, ImacConfig, ImacFabric};
use tpu_imac::systolic::{ArrayConfig, SramConfig};
use tpu_imac::util::rng::Xoshiro256;
use tpu_imac::workload::zoo;

fn main() -> anyhow::Result<()> {
    // 1. Architecture evaluation: cycles + memory for LeNet (paper row 1).
    let model = zoo::lenet();
    println!("{}", model.summary());
    let eval = arch::evaluate(&model, &ArrayConfig::default(), &SramConfig::default())?;
    println!(
        "TPU:      {:>8} cycles   {:.3} MB",
        eval.cycles_tpu,
        eval.mem.tpu_mb()
    );
    println!(
        "TPU-IMAC: {:>8} cycles   {:.3} MB (SRAM {:.3} + RRAM {:.3})",
        eval.cycles_hybrid,
        eval.mem.hybrid_mb(),
        eval.mem.sram_mb(),
        eval.mem.rram_mb()
    );
    println!(
        "=> speedup {:.2}x, memory reduction {:.2}% (paper: 2.59x, 88.34%)",
        eval.speedup(),
        eval.memory_reduction() * 100.0
    );

    // 2. One analog inference through a ternary IMAC head.
    let mut rng = Xoshiro256::seed_from_u64(1);
    let (n_in, n_hidden, n_out) = (256, 120, 10);
    let w1: Vec<i8> = (0..n_in * n_hidden).map(|_| (rng.next_below(3) as i8) - 1).collect();
    let w2: Vec<i8> = (0..n_hidden * n_out).map(|_| (rng.next_below(3) as i8) - 1).collect();
    let fabric = ImacFabric::build(
        &[(w1, n_in, n_hidden), (w2, n_hidden, n_out)],
        &ImacConfig::default(),
        AdcConfig::default(),
        0,
    );
    let x: Vec<f32> = (0..n_in).map(|_| if rng.next_u64() & 1 == 1 { 1.0 } else { -1.0 }).collect();
    let scores = fabric.forward(&x);
    println!(
        "\nIMAC head: {} subarrays, {} cycles/inference, {} B RRAM",
        fabric.subarrays_used(),
        fabric.latency_cycles(),
        fabric.rram_bytes()
    );
    println!("scores: {scores:.3?}");
    let cost = tpu_imac::imac::inference_cost(&fabric, &tpu_imac::imac::EnergyConfig::default());
    println!(
        "energy: {:.2} nJ/inference ({} device reads, {} neuron evals)",
        cost.energy_j * 1e9,
        cost.device_reads,
        cost.neuron_evals
    );
    Ok(())
}
