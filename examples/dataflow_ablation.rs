//! Dataflow ablation (Figure-2-class study): OS vs WS vs IS, pipelined vs
//! conservative folds, on the paper suite — plus the per-cycle PE wavefront
//! occupancy series from the register-level OS stepper.
//!
//! ```sh
//! cargo run --release --example dataflow_ablation
//! ```

use tpu_imac::systolic::{
    array, simulate_network, ArrayConfig, Dataflow, FoldOverlap, Schedule, SramConfig,
};
use tpu_imac::util::table::{Align, Table};
use tpu_imac::workload::zoo;

fn main() {
    // 1. Cycle totals per dataflow/overlap for every model (TPU-only, so
    //    dense layers are included — the ablation the OS choice rests on).
    let sram = SramConfig::default();
    let mut t = Table::new(&[
        "model", "OS-pipe", "OS-cons", "WS-pipe", "IS-pipe", "OS util%",
    ])
    .with_title("Dataflow ablation — total TPU cycles (32x32 array)")
    .with_aligns(&[Align::Left, Align::Right, Align::Right, Align::Right, Align::Right, Align::Right]);
    for model in zoo::paper_suite() {
        let mut cells = vec![format!("{}/{}", model.name, model.dataset.label())];
        let mut os_util = 0.0;
        for (df, ov) in [
            (Dataflow::Os, FoldOverlap::Pipelined),
            (Dataflow::Os, FoldOverlap::Conservative),
            (Dataflow::Ws, FoldOverlap::Pipelined),
            (Dataflow::Is, FoldOverlap::Pipelined),
        ] {
            let cfg = ArrayConfig { rows: 32, cols: 32, dataflow: df, overlap: ov };
            let (_, stats) = simulate_network(&cfg, &sram, &model, Schedule::TpuOnly);
            if df == Dataflow::Os && ov == FoldOverlap::Pipelined {
                os_util = stats.avg_utilization;
            }
            cells.push(format!("{}", stats.total_cycles));
        }
        cells.push(format!("{:.1}", os_util * 100.0));
        t.row(cells);
    }
    println!("{}", t.to_ascii());

    // 2. Wavefront occupancy (Figure 2a): an 8x8 OS fold with K=12.
    let a = vec![vec![1.0f32; 12]; 8];
    let b = vec![vec![1.0f32; 8]; 12];
    let run = array::run_os_fold(&a, &b);
    println!("OS 8x8 fold (K=12) wavefront — active PEs per cycle:");
    for (t, n) in run.occupancy.iter().enumerate() {
        println!("  cycle {t:>2}: {}", "#".repeat(*n as usize / 2 + 1));
    }
    println!(
        "last MAC at cycle {} (analytic r+c+K-2 = {}), drain completes at {}",
        run.cycles_to_last_mac - 1,
        8 + 8 + 12 - 2,
        run.cycles_with_drain
    );
}
