//! IMAC non-ideality study (Figure-1-class characterization): the analog
//! sigmoid VTC, plus accuracy-relevant deviation under device variation and
//! interconnect IR drop — the effects that motivate the paper's bounded
//! subarray sizes (Amin et al.'s Xbar-partitioning).
//!
//! ```sh
//! cargo run --release --example imac_noise_study [-- sigma alpha trials]
//! ```

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let sigma = args.first().and_then(|s| s.parse().ok()).unwrap_or(0.2);
    let alpha = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(0.2);
    let trials = args.get(2).and_then(|s| s.parse().ok()).unwrap_or(32);
    tpu_imac::studies::imac_noise_study(sigma, alpha, trials);
}
